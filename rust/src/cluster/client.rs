//! Pooled, reconnecting, pipelining JSONL client for remote
//! coordinators — the wire half of the remote model backend.
//!
//! A [`RemoteClient`] owns a small pool of TCP connections to one
//! backend `icr serve --listen tcp:` process. Requests are protocol-v2
//! frames tagged with a client correlation id; every connection has a
//! **reader thread demultiplexing replies by id**, so any number of
//! calls pipeline over one socket without head-of-line blocking on the
//! client side (the server already pipelines per session, `DESIGN.md`
//! §8). Error frames decode back into typed [`IcrError`]s via
//! [`protocol::decode_response`], so a remote `overloaded` or
//! `shape_mismatch` propagates through the front door exactly like a
//! local one.
//!
//! Reconnection: a connection slot found dead (EOF, write failure,
//! refused connect) is rebuilt on the next call; one retry per call
//! covers a backend restart between calls. Health checks ride the same
//! path — [`RemoteClient::probe`] is a short-timeout `stats` round trip
//! the coordinator's health monitor uses to eject dead members.
//!
//! Per-endpoint counters (connects, requests ok/failed, request_latency
//! histogram, outstanding) live in a [`Registry`] surfaced by the
//! `cluster` stats section.
//!
//! Resilience hooks (`DESIGN.md` §12): every timeout is configurable via
//! [`RemoteTimeouts`] (`--remote-call-timeout-ms` and friends, defaults
//! unchanged); data wires accept an optional [`FaultInjector`] that
//! schedules deterministic injected errors/drops/delays *before* frames
//! reach the socket (control probes are never faulted, so a
//! request-flapping member stays probe-healthy — the circuit breaker's
//! case); and replies that arrive after their call already timed out are
//! classified by a bounded cancelled-id set as the `late_replies`
//! counter instead of being mistaken for unmatched protocol frames.

use std::collections::{HashMap, HashSet, VecDeque};
use std::io::{Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use super::fault::{FaultInjector, FaultScope};
use crate::coordinator::protocol::{self, RequestFrame};
use crate::coordinator::request::{Request, Response};
use crate::error::IcrError;
use crate::json::Value;
use crate::metrics::Registry;
use crate::model::ModelInfo;

/// How long one remote call may take before the client gives up. Wide —
/// inference sweeps are legitimate wire ops.
pub const CALL_TIMEOUT: Duration = Duration::from_secs(120);
/// Health probes answer fast or count as dead.
pub const PROBE_TIMEOUT: Duration = Duration::from_secs(2);
/// TCP connect budget per address candidate (data wires).
const CONNECT_TIMEOUT: Duration = Duration::from_secs(5);
/// Control-wire connect budget: probes must stay cheap even against a
/// blackholed host (SYN dropped, not refused), or one dead member's
/// probe would stall the whole health cycle past the interval.
const PROBE_CONNECT_TIMEOUT: Duration = Duration::from_secs(1);
/// Reader poll granularity (shutdown-flag checks between reads).
const READ_POLL: Duration = Duration::from_millis(50);
/// Connections per endpoint. Two sockets keep a slow panel fan-out from
/// serializing behind a long inference on the same wire.
pub const DEFAULT_POOL: usize = 2;
/// Abandoned correlation ids remembered per wire for `late_replies`
/// classification. Bounded: a pathological flood of timeouts evicts the
/// oldest ids rather than growing without bound.
const CANCELLED_CAP: usize = 1024;

/// Wire timeouts for one remote endpoint, resolved from
/// `--remote-call-timeout-ms` / `--remote-probe-timeout-ms` /
/// `--remote-connect-timeout-ms` by [`crate::config::ServerConfig::
/// remote_timeouts`]. Defaults match the historical constants, so the
/// knobs change nothing unless set.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RemoteTimeouts {
    /// Budget for one data round trip ([`CALL_TIMEOUT`]).
    pub call: Duration,
    /// Budget for a health-probe round trip ([`PROBE_TIMEOUT`]).
    pub probe: Duration,
    /// TCP connect budget per address candidate on data wires.
    pub connect: Duration,
}

impl Default for RemoteTimeouts {
    fn default() -> Self {
        RemoteTimeouts { call: CALL_TIMEOUT, probe: PROBE_TIMEOUT, connect: CONNECT_TIMEOUT }
    }
}

/// Bounded memory of correlation ids whose callers gave up (timeout in
/// [`RemoteClient::finish`]). Insertion-ordered ring for eviction, set
/// for membership; a late reply matching an entry is hygiene
/// (`late_replies`), anything else is a protocol bug
/// (`frames_unmatched`).
struct CancelledIds {
    order: VecDeque<u64>,
    set: HashSet<u64>,
}

impl CancelledIds {
    fn new() -> CancelledIds {
        CancelledIds { order: VecDeque::new(), set: HashSet::new() }
    }

    fn insert(&mut self, id: u64) {
        if self.set.insert(id) {
            self.order.push_back(id);
            while self.order.len() > CANCELLED_CAP {
                if let Some(old) = self.order.pop_front() {
                    self.set.remove(&old);
                }
            }
        }
    }

    /// Membership test that consumes the entry. The ring keeps the id
    /// until it ages out by cap; stale ring slots are harmless because
    /// the set is the membership authority.
    fn take(&mut self, id: u64) -> bool {
        self.set.remove(&id)
    }
}

/// What a wire reader delivers per reply: the decoded result plus the
/// shard's echoed trace document, when the request carried a context
/// (`DESIGN.md` §13).
type ReplyPayload = (Result<Response, IcrError>, Option<Value>);

/// One live connection: a locked write half plus the reply-demux map its
/// reader thread serves.
struct Wire {
    writer: Mutex<TcpStream>,
    pending: Mutex<HashMap<u64, mpsc::Sender<ReplyPayload>>>,
    /// Ids [`RemoteClient::finish`] abandoned on timeout; their replies,
    /// if they ever land, count as `late_replies` (see [`CancelledIds`]).
    cancelled: Mutex<CancelledIds>,
    dead: AtomicBool,
    shutdown: AtomicBool,
}

impl Wire {
    /// Fail every waiting call — the reader exits, the peer is gone.
    fn fail_pending(&self, endpoint: &str) {
        let mut pending = self.pending.lock().unwrap();
        for (_, tx) in pending.drain() {
            let _ = tx.send((
                Err(IcrError::Backend(format!("remote {endpoint} closed the connection"))),
                None,
            ));
        }
    }
}

/// One in-flight call returned by [`RemoteClient::submit`]: the reply
/// receiver plus enough identity to cancel the wire's demux entry if
/// the caller gives up (see [`RemoteClient::finish`]).
pub struct PendingReply {
    rx: mpsc::Receiver<ReplyPayload>,
    /// The wire the frame went out on and its correlation id; `None`
    /// when the request never made it onto a wire (the error is already
    /// queued on `rx`).
    sent: Option<(std::sync::Weak<Wire>, u64)>,
}

/// Pooled pipelining client for one remote endpoint.
pub struct RemoteClient {
    /// `HOST:PORT` (what the sockets dial).
    addr: String,
    /// `tcp:HOST:PORT` (what stats and errors print).
    endpoint: String,
    slots: Vec<Mutex<Option<Arc<Wire>>>>,
    /// Dedicated control connection for health probes (and `describe`).
    /// Backend sessions reply in submission order per connection, so a
    /// probe sharing a data wire would queue behind long inferences and
    /// time out spuriously — control traffic gets its own socket.
    control: Mutex<Option<Arc<Wire>>>,
    rr: AtomicUsize,
    next_id: AtomicU64,
    metrics: Registry,
    timeouts: RemoteTimeouts,
    /// Chaos seam: when armed, data-wire submits consult the injector
    /// before touching the socket. Control traffic never does.
    fault: Option<Arc<FaultInjector>>,
}

impl RemoteClient {
    /// Client for `addr` (`tcp:HOST:PORT`, or bare `HOST:PORT`). Lazy —
    /// no connection is made until the first call. Default timeouts, no
    /// fault injection.
    pub fn new(addr: &str, pool: usize) -> Result<RemoteClient, IcrError> {
        RemoteClient::with_options(addr, pool, RemoteTimeouts::default(), None)
    }

    /// [`RemoteClient::new`] with explicit timeouts and an optional
    /// fault injector — the path `ServerConfig` resolves through.
    pub fn with_options(
        addr: &str,
        pool: usize,
        timeouts: RemoteTimeouts,
        fault: Option<Arc<FaultInjector>>,
    ) -> Result<RemoteClient, IcrError> {
        let hostport = addr.strip_prefix("tcp:").unwrap_or(addr).trim().to_string();
        // One grammar for everyone: the same validator the config
        // parsers run, so CLI-accepted and client-accepted addresses
        // can never diverge.
        let endpoint = crate::config::validate_remote_addr(&format!("tcp:{hostport}"))
            .map_err(|e| IcrError::InvalidParameter(format!("{e:#}")))?;
        let slots = (0..pool.max(1)).map(|_| Mutex::new(None)).collect();
        Ok(RemoteClient {
            addr: hostport,
            endpoint,
            slots,
            control: Mutex::new(None),
            rr: AtomicUsize::new(0),
            next_id: AtomicU64::new(1),
            metrics: Registry::new(),
            timeouts,
            fault,
        })
    }

    /// `tcp:HOST:PORT`.
    pub fn endpoint(&self) -> &str {
        &self.endpoint
    }

    /// The wire timeouts this client was built with.
    pub fn timeouts(&self) -> RemoteTimeouts {
        self.timeouts
    }

    /// Per-endpoint counters: `connects`, `requests_ok`,
    /// `requests_failed`, `request_latency`, gauge `outstanding`.
    pub fn metrics(&self) -> &Registry {
        &self.metrics
    }

    /// Requests currently awaiting a reply across the pool.
    pub fn outstanding(&self) -> usize {
        self.slots
            .iter()
            .map(|slot| {
                slot.lock()
                    .unwrap()
                    .as_ref()
                    .map(|w| w.pending.lock().unwrap().len())
                    .unwrap_or(0)
            })
            .sum()
    }

    fn connect(&self, connect_timeout: Duration) -> Result<Arc<Wire>, IcrError> {
        let mut last: Option<std::io::Error> = None;
        let addrs = self
            .addr
            .to_socket_addrs()
            .map_err(|e| IcrError::Backend(format!("resolving {}: {e}", self.endpoint)))?;
        let mut stream: Option<TcpStream> = None;
        for sa in addrs {
            match TcpStream::connect_timeout(&sa, connect_timeout) {
                Ok(s) => {
                    stream = Some(s);
                    break;
                }
                Err(e) => last = Some(e),
            }
        }
        let stream = stream.ok_or_else(|| {
            IcrError::Backend(format!(
                "connecting {}: {}",
                self.endpoint,
                last.map(|e| e.to_string()).unwrap_or_else(|| "no addresses".into())
            ))
        })?;
        stream.set_nodelay(true).ok();
        let read_half = stream
            .try_clone()
            .map_err(|e| IcrError::Backend(format!("cloning socket to {}: {e}", self.endpoint)))?;
        let wire = Arc::new(Wire {
            writer: Mutex::new(stream),
            pending: Mutex::new(HashMap::new()),
            cancelled: Mutex::new(CancelledIds::new()),
            dead: AtomicBool::new(false),
            shutdown: AtomicBool::new(false),
        });
        let reader_wire = wire.clone();
        let endpoint = self.endpoint.clone();
        let metrics = self.metrics.clone();
        std::thread::Builder::new()
            .name("icr-remote-reader".into())
            .spawn(move || reader_loop(reader_wire, read_half, endpoint, metrics))
            .map_err(|e| IcrError::Backend(format!("spawning remote reader: {e}")))?;
        self.metrics.counter("connects").inc();
        Ok(wire)
    }

    /// A live wire in `slot`, rebuilding it when dead.
    fn wire_in(
        &self,
        slot: &Mutex<Option<Arc<Wire>>>,
        connect_timeout: Duration,
    ) -> Result<Arc<Wire>, IcrError> {
        let mut guard = slot.lock().unwrap();
        if let Some(w) = guard.as_ref() {
            if !w.dead.load(Ordering::SeqCst) {
                return Ok(w.clone());
            }
            w.shutdown.store(true, Ordering::SeqCst);
        }
        let fresh = self.connect(connect_timeout)?;
        *guard = Some(fresh.clone());
        Ok(fresh)
    }

    /// A live data wire from the pool (round-robin), or the control wire.
    fn wire(&self, control: bool) -> Result<Arc<Wire>, IcrError> {
        if control {
            return self.wire_in(&self.control, PROBE_CONNECT_TIMEOUT);
        }
        self.wire_in(
            &self.slots[self.rr.fetch_add(1, Ordering::Relaxed) % self.slots.len()],
            self.timeouts.connect,
        )
    }

    /// Send one request and return a pending handle immediately — the
    /// pipelining primitive. Retries once on a freshly dead wire so a
    /// backend restart between calls is invisible. Every `submit` must
    /// be paired with one [`Self::finish`] (which settles the
    /// `outstanding` gauge and outcome counters, and cancels the demux
    /// entry on timeout).
    pub fn submit(&self, model: Option<&str>, request: Request) -> PendingReply {
        self.submit_on(false, model, request, None)
    }

    /// [`Self::submit`] with a protocol trace context to propagate
    /// (`DESIGN.md` §13). `None` keeps the frame byte-identical to an
    /// untraced one.
    pub fn submit_traced(
        &self,
        model: Option<&str>,
        request: Request,
        trace: Option<Value>,
    ) -> PendingReply {
        self.submit_on(false, model, request, trace)
    }

    fn submit_on(
        &self,
        control: bool,
        model: Option<&str>,
        request: Request,
        trace: Option<Value>,
    ) -> PendingReply {
        self.metrics.gauge("outstanding").inc();
        // Chaos seam: an armed injector may fail the call before it
        // reaches the socket (probes never pass through here with
        // `control=false`, so a request-faulted member stays
        // probe-healthy). Delays are applied inline and fall through.
        if !control {
            if let Some(fault) = &self.fault {
                if let Some(err) = fault.apply(FaultScope::Remote) {
                    let (tx, rx) = mpsc::channel();
                    let _ = tx.send((Err(err), None));
                    return PendingReply { rx, sent: None };
                }
            }
        }
        let mut last_err: Option<IcrError> = None;
        // Control traffic (probes) gets ONE attempt: a failed probe is
        // itself the signal, and the health monitor retries next
        // interval anyway — retrying here would double a dead member's
        // stall inside the health cycle.
        let attempts = if control { 1 } else { 2 };
        for _attempt in 0..attempts {
            let wire = match self.wire(control) {
                Ok(w) => w,
                Err(e) => {
                    last_err = Some(e);
                    continue;
                }
            };
            // Fresh channel per attempt: a reply (or failure) from an
            // abandoned earlier wire can never shadow the live attempt.
            let (tx, rx) = mpsc::channel();
            let id = self.next_id.fetch_add(1, Ordering::Relaxed);
            let frame = match &trace {
                Some(t) => RequestFrame::v2(model, Some(id), request.clone()).with_trace(t.clone()),
                None => RequestFrame::v2(model, Some(id), request.clone()),
            };
            let line = protocol::encode_request(&frame).to_json();
            wire.pending.lock().unwrap().insert(id, tx);
            let wrote = {
                let mut w = wire.writer.lock().unwrap();
                writeln!(w, "{line}").and_then(|_| w.flush())
            };
            match wrote {
                Ok(()) => {
                    // Close the submit/reader race: the reader stores
                    // `dead` BEFORE draining the pending map, so if it
                    // died around our insert (its drain may have run
                    // first, orphaning the entry) this re-check is
                    // guaranteed to see it — fail fast and retry instead
                    // of waiting out the full call timeout.
                    if wire.dead.load(Ordering::SeqCst) {
                        wire.pending.lock().unwrap().remove(&id);
                        last_err = Some(IcrError::Backend(format!(
                            "remote {} closed during submit",
                            self.endpoint
                        )));
                        continue;
                    }
                    return PendingReply { rx, sent: Some((Arc::downgrade(&wire), id)) };
                }
                Err(e) => {
                    wire.pending.lock().unwrap().remove(&id);
                    wire.dead.store(true, Ordering::SeqCst);
                    wire.shutdown.store(true, Ordering::SeqCst);
                    last_err =
                        Some(IcrError::Backend(format!("writing to {}: {e}", self.endpoint)));
                }
            }
        }
        let (tx, rx) = mpsc::channel();
        let _ = tx.send((
            Err(last_err.unwrap_or_else(|| {
                IcrError::Backend(format!("remote {} unavailable", self.endpoint))
            })),
            None,
        ));
        PendingReply { rx, sent: None }
    }

    /// Await one submitted reply with the given timeout, recording
    /// latency and outcome counters. On timeout the correlation-id entry
    /// is removed from the wire's demux map, so abandoned calls never
    /// leak map entries or phantom `outstanding()` counts.
    pub fn finish(
        &self,
        pending: &PendingReply,
        t0: Instant,
        timeout: Duration,
    ) -> Result<Response, IcrError> {
        self.finish_traced(pending, t0, timeout).0
    }

    /// [`Self::finish`], also returning the shard's echoed trace
    /// document when the reply frame carried one (`DESIGN.md` §13).
    pub fn finish_traced(
        &self,
        pending: &PendingReply,
        t0: Instant,
        timeout: Duration,
    ) -> (Result<Response, IcrError>, Option<Value>) {
        let (result, trace) = match pending.rx.recv_timeout(timeout) {
            Ok(payload) => payload,
            Err(_) => {
                if let Some((wire, id)) = &pending.sent {
                    if let Some(w) = wire.upgrade() {
                        // Remember the abandoned id (only if the reply
                        // has not already been dispatched) so a
                        // straggler reply counts as `late_replies`,
                        // not `frames_unmatched`.
                        if w.pending.lock().unwrap().remove(id).is_some() {
                            w.cancelled.lock().unwrap().insert(*id);
                        }
                    }
                }
                (
                    Err(IcrError::Backend(format!(
                        "remote {} timed out after {:.1}s",
                        self.endpoint,
                        timeout.as_secs_f64()
                    ))),
                    None,
                )
            }
        };
        self.metrics.gauge("outstanding").dec();
        self.metrics.histogram("request_latency").observe(t0);
        match &result {
            Ok(_) => self.metrics.counter("requests_ok").inc(),
            Err(_) => self.metrics.counter("requests_failed").inc(),
        }
        (result, trace)
    }

    /// One blocking round trip with the configured call timeout.
    pub fn call(&self, model: Option<&str>, request: Request) -> Result<Response, IcrError> {
        self.call_with_timeout(model, request, self.timeouts.call)
    }

    pub fn call_with_timeout(
        &self,
        model: Option<&str>,
        request: Request,
        timeout: Duration,
    ) -> Result<Response, IcrError> {
        let t0 = Instant::now();
        let pending = self.submit(model, request);
        self.finish(&pending, t0, timeout)
    }

    /// Short-timeout liveness check (a `stats` round trip on the control
    /// connection, so it never queues behind long data requests) — the
    /// health monitor's probe.
    pub fn probe(&self) -> Result<(), IcrError> {
        let t0 = Instant::now();
        let pending = self.submit_on(true, None, Request::Stats, None);
        self.finish(&pending, t0, self.timeouts.probe).map(|_| ())
    }

    /// Fetch the identity of the remote model (`None` = remote default),
    /// over the control connection.
    pub fn describe(&self, model: Option<&str>) -> Result<ModelInfo, IcrError> {
        let t0 = Instant::now();
        let pending = self.submit_on(true, model, Request::Describe, None);
        match self.finish(&pending, t0, self.timeouts.call)? {
            Response::Describe(info) => Ok(info),
            other => Err(IcrError::Backend(format!(
                "remote {} answered describe with {other:?}",
                self.endpoint
            ))),
        }
    }
}

impl Drop for RemoteClient {
    fn drop(&mut self) {
        // Readers poll the shutdown flag; without this they would park on
        // their sockets until the server hangs up.
        for slot in self.slots.iter().chain(std::iter::once(&self.control)) {
            if let Some(w) = slot.lock().unwrap().as_ref() {
                w.shutdown.store(true, Ordering::SeqCst);
            }
        }
    }
}

/// Demultiplex reply frames by correlation id until EOF, socket error or
/// client shutdown. Partial lines survive read-timeout polls (same
/// framing discipline as `net::session::LineReader`).
fn reader_loop(wire: Arc<Wire>, mut stream: TcpStream, endpoint: String, metrics: Registry) {
    let _ = stream.set_read_timeout(Some(READ_POLL));
    let mut pending_bytes: Vec<u8> = Vec::new();
    let mut buf = [0u8; 8192];
    'outer: loop {
        // Dispatch every complete line already buffered.
        while let Some(pos) = pending_bytes.iter().position(|&b| b == b'\n') {
            let rest = pending_bytes.split_off(pos + 1);
            let mut line = std::mem::replace(&mut pending_bytes, rest);
            line.pop();
            if line.last() == Some(&b'\r') {
                line.pop();
            }
            dispatch(&wire, &line, &metrics);
        }
        if wire.shutdown.load(Ordering::SeqCst) {
            break 'outer;
        }
        match stream.read(&mut buf) {
            Ok(0) => break 'outer,
            Ok(n) => pending_bytes.extend_from_slice(&buf[..n]),
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                        | std::io::ErrorKind::Interrupted
                ) => {}
            Err(_) => break 'outer,
        }
    }
    wire.dead.store(true, Ordering::SeqCst);
    wire.fail_pending(&endpoint);
}

fn dispatch(wire: &Wire, line: &[u8], metrics: &Registry) {
    let text = String::from_utf8_lossy(line);
    if text.trim().is_empty() {
        return;
    }
    let frame = Value::parse(&text).ok().and_then(|v| protocol::decode_response(&v).ok());
    match frame {
        Some(frame) => {
            let tx = wire.pending.lock().unwrap().remove(&frame.id);
            match tx {
                Some(tx) => {
                    let _ = tx.send((frame.result, frame.trace));
                }
                // No waiter: either the caller timed out and cancelled
                // (hygiene — count, never deliver) or the server sent
                // an id we never issued (a protocol bug).
                None if wire.cancelled.lock().unwrap().take(frame.id) => {
                    metrics.counter("late_replies").inc();
                }
                None => {
                    metrics.counter("frames_unmatched").inc();
                }
            }
        }
        None => metrics.counter("frames_undecodable").inc(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn address_validation() {
        assert!(RemoteClient::new("tcp:127.0.0.1:7777", 2).is_ok());
        assert!(RemoteClient::new("127.0.0.1:7777", 1).is_ok());
        assert_eq!(
            RemoteClient::new("tcp:localhost:1234", 2).unwrap().endpoint(),
            "tcp:localhost:1234"
        );
        for bad in ["", "tcp:", "tcp:host", "tcp::7777", "tcp:host:notaport", "unix:/x"] {
            assert!(RemoteClient::new(bad, 2).is_err(), "{bad:?} should not parse");
        }
    }

    #[test]
    fn timed_out_calls_cancel_their_pending_entries() {
        // A server that accepts and never answers: the call must time
        // out typed AND remove its demux entry (no leak, no phantom
        // outstanding count).
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = format!("tcp:{}", listener.local_addr().unwrap());
        let silent = std::thread::spawn(move || {
            listener.set_nonblocking(true).unwrap();
            let mut conns = Vec::new();
            let t0 = Instant::now();
            while t0.elapsed() < Duration::from_secs(3) {
                if let Ok((s, _)) = listener.accept() {
                    conns.push(s);
                }
                std::thread::sleep(Duration::from_millis(5));
            }
        });
        let c = RemoteClient::new(&addr, 1).unwrap();
        match c.call_with_timeout(None, Request::Stats, Duration::from_millis(200)) {
            Err(IcrError::Backend(msg)) => assert!(msg.contains("timed out"), "{msg}"),
            other => panic!("expected timeout, got {other:?}"),
        }
        assert_eq!(c.outstanding(), 0, "timed-out call leaked a pending demux entry");
        assert_eq!(c.metrics().counter("requests_failed").get(), 1);
        drop(c);
        let _ = silent.join();
    }

    #[test]
    fn default_timeouts_match_historical_constants() {
        let t = RemoteTimeouts::default();
        assert_eq!(t.call, CALL_TIMEOUT);
        assert_eq!(t.probe, PROBE_TIMEOUT);
        assert_eq!(t.connect, Duration::from_secs(5));
        assert_eq!(RemoteClient::new("tcp:127.0.0.1:7777", 1).unwrap().timeouts(), t);
    }

    #[test]
    fn injected_remote_faults_fire_before_the_socket_and_spare_probes() {
        // error=1.0 on the remote scope: every data call fails with the
        // injected typed error without a single connect; control probes
        // bypass the injector entirely (the probe fails here only
        // because nothing listens on the port).
        let inj = Arc::new(FaultInjector::from_spec("remote:error=1", 7).unwrap());
        let c = RemoteClient::with_options(
            "tcp:127.0.0.1:9",
            1,
            RemoteTimeouts::default(),
            Some(inj.clone()),
        )
        .unwrap();
        match c.call_with_timeout(None, Request::Stats, Duration::from_secs(1)) {
            Err(e) => {
                assert!(e.is_member_fault(), "{e}");
                assert!(e.to_string().contains("injected fault"), "{e}");
            }
            Ok(other) => panic!("expected injected fault, got {other:?}"),
        }
        assert_eq!(inj.injected_errors(), 1);
        assert_eq!(c.metrics().counter("connects").get(), 0, "fault fired before the socket");
        assert_eq!(c.outstanding(), 0);
        assert!(c.probe().is_err());
        assert_eq!(inj.injected_errors(), 1, "probes are never faulted");
    }

    #[test]
    fn late_replies_count_as_hygiene_not_unmatched_frames() {
        // Demux-entry hygiene under abandonment stress: a server that
        // withholds every reply until after the client has timed out
        // and cancelled. The straggler frames must be classified as
        // `late_replies` (counted, never delivered), `frames_unmatched`
        // must stay zero, and `outstanding` must settle at zero.
        use std::io::{BufRead, BufReader};
        const CALLS: usize = 8;
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = format!("tcp:{}", listener.local_addr().unwrap());
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut ids = Vec::new();
            let mut line = String::new();
            while ids.len() < CALLS {
                line.clear();
                if reader.read_line(&mut line).unwrap() == 0 {
                    break;
                }
                let (_, id) = protocol::frame_error_context(&line);
                ids.push(id.expect("v2 frames carry correlation ids"));
            }
            // Wait until every finish() below has timed out.
            std::thread::sleep(Duration::from_millis(800));
            let mut w = stream;
            for id in ids {
                let reply =
                    protocol::encode_response(2, id, None, &Err(IcrError::Backend("slow".into())), None);
                writeln!(w, "{}", reply.to_json()).unwrap();
            }
            w.flush().unwrap();
            // Keep the socket open while the client reader drains the
            // stragglers.
            std::thread::sleep(Duration::from_millis(700));
        });
        let c = RemoteClient::new(&addr, 1).unwrap();
        let t0 = Instant::now();
        let pendings: Vec<PendingReply> =
            (0..CALLS).map(|_| c.submit(None, Request::Stats)).collect();
        for p in &pendings {
            match c.finish(p, t0, Duration::from_millis(50)) {
                Err(IcrError::Backend(msg)) => assert!(msg.contains("timed out"), "{msg}"),
                other => panic!("expected timeout, got {other:?}"),
            }
        }
        assert_eq!(c.outstanding(), 0, "cancelled calls left phantom demux entries");
        let deadline = Instant::now() + Duration::from_secs(5);
        while c.metrics().counter("late_replies").get() < CALLS as u64 {
            assert!(Instant::now() < deadline, "late replies never classified");
            std::thread::sleep(Duration::from_millis(10));
        }
        assert_eq!(c.metrics().counter("late_replies").get(), CALLS as u64);
        assert_eq!(c.metrics().counter("frames_unmatched").get(), 0);
        drop(c);
        let _ = server.join();
    }

    #[test]
    fn unreachable_endpoint_fails_typed_not_hanging() {
        // Port 1 on localhost refuses immediately; the error must be a
        // typed backend failure delivered through the receiver.
        let c = RemoteClient::new("tcp:127.0.0.1:1", 1).unwrap();
        match c.call_with_timeout(None, Request::Stats, Duration::from_secs(10)) {
            Err(IcrError::Backend(msg)) => assert!(msg.contains("127.0.0.1:1"), "{msg}"),
            other => panic!("expected backend error, got {other:?}"),
        }
        assert_eq!(c.metrics().counter("requests_failed").get(), 1);
        assert!(c.probe().is_err());
        assert_eq!(c.outstanding(), 0);
    }
}
