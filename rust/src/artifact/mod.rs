//! Versioned on-disk model artifacts (`DESIGN.md` §10): snapshots a
//! served model can be saved to, verified against, and rebuilt from.
//!
//! The paper's headline model has 122 billion parameters — state you
//! ship between processes, not something you recompute at every boot.
//! An artifact is a directory holding one `manifest.json` plus raw
//! binary payloads, mirroring the AOT manifest+payload split the
//! [`crate::runtime`] uses for HLO executables:
//!
//! - `manifest.json` — schema version, registry name, backend family,
//!   the full [`ModelConfig`], a SHA-256 **config checksum** over the
//!   config's canonical JSON, the [`ModelDescriptor`], hardware /
//!   determinism provenance (crate version, avx2/fma, core count,
//!   apply_threads), and one `{path, kind, sha256, len}` record per
//!   payload.
//! - `domain.bin` — modeled locations (little-endian `f64`).
//! - `obs.bin` — observation indices (little-endian `u64`).
//! - `xi.bin` — optional optimized excitations ξ (the posterior state a
//!   warm-started `infer` resumes from).
//!
//! [`load`] re-verifies every payload digest and the config checksum and
//! rejects mismatches with typed errors
//! ([`IcrError::ArtifactCorrupt`] / [`IcrError::ChecksumMismatch`]).
//! Because samples are pure functions of `(seed, config)` (`DESIGN.md`
//! §4), a model rebuilt from a verified artifact produces byte-identical
//! samples to the model that saved it; [`Snapshot::verify_model`] pins
//! that contract by comparing the rebuilt geometry, domain and
//! observation pattern bitwise against the stored payloads.
//!
//! The same checksum function guards the cluster front door: a remote
//! shard's `describe` reply carries its config checksum, and the health
//! monitor refuses to route to a member whose checksum mismatches the
//! declared spec (`DESIGN.md` §9/§10).

pub mod payload;
pub mod sha256;

use std::fs;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::config::{Backend, ModelConfig};
use crate::error::IcrError;
use crate::json::{self, Value};
use crate::model::{GpModel, ModelDescriptor};

/// Artifact schema version; bumped on incompatible manifest changes.
pub const SCHEMA_VERSION: u64 = 1;

/// Manifest file name inside an artifact directory.
pub const MANIFEST_FILE: &str = "manifest.json";

/// SHA-256 hex checksum of a model configuration's canonical JSON
/// encoding. Object keys serialize in sorted order, so the encoding —
/// and therefore the checksum — is deterministic across processes. This
/// is the single identity function shared by artifact verification and
/// the remote `describe`-time shard check.
pub fn config_checksum(cfg: &ModelConfig) -> String {
    sha256::hex_digest(cfg.to_json().to_json().as_bytes())
}

/// Hardware/determinism provenance recorded at save time. Samples do
/// not depend on any of these knobs (`DESIGN.md` §4/§6), so provenance
/// is diagnostic — it answers "what produced this artifact", it does not
/// gate loading.
#[derive(Debug, Clone, PartialEq)]
pub struct Provenance {
    /// Crate version that wrote the artifact.
    pub version: String,
    /// AVX2 available on the saving host.
    pub avx2: bool,
    /// FMA available on the saving host.
    pub fma: bool,
    /// Core count of the saving host.
    pub cores: usize,
    /// Configured `--apply-threads` of the saving process.
    pub apply_threads: usize,
}

impl Provenance {
    /// Capture the current process's provenance.
    pub fn capture(apply_threads: usize) -> Provenance {
        let feat = crate::parallel::cpu_features();
        Provenance {
            version: crate::VERSION.to_string(),
            avx2: feat.avx2,
            fma: feat.fma,
            cores: feat.cores,
            apply_threads,
        }
    }

    fn to_json(&self) -> Value {
        json::obj(vec![
            ("version", json::s(&self.version)),
            ("avx2", Value::Bool(self.avx2)),
            ("fma", Value::Bool(self.fma)),
            ("cores", json::num(self.cores as f64)),
            ("apply_threads", json::num(self.apply_threads as f64)),
        ])
    }

    fn from_json(v: &Value) -> Provenance {
        Provenance {
            version: v.get("version").and_then(Value::as_str).unwrap_or("").to_string(),
            avx2: v.get("avx2").and_then(Value::as_bool).unwrap_or(false),
            fma: v.get("fma").and_then(Value::as_bool).unwrap_or(false),
            cores: v.get("cores").and_then(Value::as_usize).unwrap_or(0),
            apply_threads: v.get("apply_threads").and_then(Value::as_usize).unwrap_or(0),
        }
    }
}

/// One payload record in the manifest.
#[derive(Debug, Clone, PartialEq)]
struct Entry {
    path: String,
    kind: &'static str,
    sha256: String,
    len: usize,
}

/// In-memory image of an artifact: everything [`save`] writes and
/// [`load`] verifies.
#[derive(Debug, Clone)]
pub struct Snapshot {
    /// Registry name the model was saved under.
    pub name: String,
    /// Engine family that rebuilds the model.
    pub backend: Backend,
    /// Full model configuration (the checksum's input).
    pub config: ModelConfig,
    /// Descriptor of the saved model.
    pub descriptor: ModelDescriptor,
    /// Modeled domain locations (bitwise parity reference at load).
    pub domain: Vec<f64>,
    /// Observation pattern.
    pub obs: Vec<usize>,
    /// Optimized excitations ξ from a posterior MAP run, if saved; a
    /// warm-started `infer` resumes chain 0 from here.
    pub posterior: Option<Vec<f64>>,
    /// Hardware/determinism provenance of the saving process.
    pub provenance: Provenance,
}

impl Snapshot {
    /// Capture a snapshot of a live model. Remote proxies cannot be
    /// snapshotted — the state lives with the backend process.
    pub fn capture(
        name: &str,
        backend: Backend,
        config: &ModelConfig,
        model: &dyn GpModel,
        posterior: Option<Vec<f64>>,
        apply_threads: usize,
    ) -> Result<Snapshot, IcrError> {
        if backend == Backend::Remote {
            return Err(IcrError::Unsupported(
                "cannot snapshot a remote proxy; save on the backend process".into(),
            ));
        }
        if let Some(xi) = &posterior {
            let dof = model.total_dof();
            if xi.len() != dof {
                return Err(IcrError::ShapeMismatch {
                    what: "posterior",
                    expected: dof,
                    got: xi.len(),
                });
            }
        }
        Ok(Snapshot {
            name: name.to_string(),
            backend,
            config: config.clone(),
            descriptor: model.descriptor(),
            domain: model.domain_points(),
            obs: model.obs_indices(),
            posterior,
            provenance: Provenance::capture(apply_threads),
        })
    }

    /// Config checksum of this snapshot.
    pub fn config_sha256(&self) -> String {
        config_checksum(&self.config)
    }

    /// A [`crate::model::ModelBuilder`] configured to rebuild this
    /// snapshot's model (config + backend); the caller layers on
    /// process-local knobs (executor, AOT artifact dir) before `build()`.
    pub fn builder(&self) -> crate::model::ModelBuilder {
        crate::model::ModelBuilder::from_config(self.config.clone()).backend(self.backend)
    }

    /// Pin the byte-identity contract: a model rebuilt from this
    /// snapshot's config must reproduce the stored geometry, domain
    /// points (bitwise) and observation pattern. A mismatch means the
    /// refinement/chart/kernel code drifted since the artifact was saved
    /// — loading it would silently produce different samples, so this
    /// rejects with a typed error instead.
    pub fn verify_model(&self, model: &dyn GpModel) -> Result<(), IcrError> {
        let d = model.descriptor();
        if (d.n, d.dof) != (self.descriptor.n, self.descriptor.dof) {
            return Err(IcrError::ChecksumMismatch {
                what: "model geometry".into(),
                expected: format!("n={} dof={}", self.descriptor.n, self.descriptor.dof),
                got: format!("n={} dof={}", d.n, d.dof),
            });
        }
        let domain = model.domain_points();
        if domain.len() != self.domain.len()
            || domain.iter().zip(&self.domain).any(|(a, b)| a.to_bits() != b.to_bits())
        {
            return Err(IcrError::ChecksumMismatch {
                what: "domain points".into(),
                expected: format!("{} stored values", self.domain.len()),
                got: "rebuilt domain differs bitwise".into(),
            });
        }
        if model.obs_indices() != self.obs {
            return Err(IcrError::ChecksumMismatch {
                what: "observation pattern".into(),
                expected: format!("{} stored indices", self.obs.len()),
                got: "rebuilt pattern differs".into(),
            });
        }
        Ok(())
    }
}

/// Write a snapshot to `dir` (created if missing): payloads first, then
/// the manifest naming their digests, so a torn save is detectable (a
/// manifest only ever references fully written payloads).
pub fn save(dir: &Path, snap: &Snapshot) -> Result<(), IcrError> {
    fs::create_dir_all(dir)
        .map_err(|e| IcrError::ArtifactCorrupt(format!("create {}: {e}", dir.display())))?;
    let mut entries = Vec::new();
    let mut write = |file: &str, kind: &'static str, bytes: Vec<u8>| -> Result<(), IcrError> {
        let path = dir.join(file);
        fs::write(&path, &bytes)
            .map_err(|e| IcrError::ArtifactCorrupt(format!("write {}: {e}", path.display())))?;
        entries.push(Entry {
            path: file.to_string(),
            kind,
            sha256: sha256::hex_digest(&bytes),
            len: bytes.len(),
        });
        Ok(())
    };
    write("domain.bin", "domain_f64", payload::encode_f64s(&snap.domain))?;
    write("obs.bin", "obs_u64", payload::encode_u64s(&snap.obs))?;
    if let Some(xi) = &snap.posterior {
        write("xi.bin", "posterior_f64", payload::encode_f64s(xi))?;
    }
    let manifest = json::obj(vec![
        ("schema_version", json::num(SCHEMA_VERSION as f64)),
        ("name", json::s(&snap.name)),
        ("backend", json::s(snap.backend.name())),
        ("config", snap.config.to_json()),
        ("config_sha256", json::s(&snap.config_sha256())),
        ("descriptor", snap.descriptor.to_json()),
        ("provenance", snap.provenance.to_json()),
        (
            "entries",
            json::arr(
                entries
                    .iter()
                    .map(|e| {
                        json::obj(vec![
                            ("path", json::s(&e.path)),
                            ("kind", json::s(e.kind)),
                            ("sha256", json::s(&e.sha256)),
                            ("len", json::num(e.len as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    let path = dir.join(MANIFEST_FILE);
    fs::write(&path, manifest.to_json_pretty())
        .map_err(|e| IcrError::ArtifactCorrupt(format!("write {}: {e}", path.display())))?;
    Ok(())
}

fn manifest_str<'a>(v: &'a Value, key: &str) -> Result<&'a str, IcrError> {
    v.get(key)
        .and_then(Value::as_str)
        .ok_or_else(|| IcrError::ArtifactCorrupt(format!("manifest missing {key:?}")))
}

/// Read and fully verify an artifact directory: manifest shape, schema
/// version, per-payload lengths and SHA-256 digests, and the config
/// checksum. Every failure is a typed [`IcrError`] so the `reload_model`
/// op can surface it as a protocol-v2 error frame.
pub fn load(dir: &Path) -> Result<Snapshot, IcrError> {
    let manifest_path = dir.join(MANIFEST_FILE);
    let text = fs::read_to_string(&manifest_path).map_err(|e| {
        IcrError::ArtifactCorrupt(format!("read {}: {e}", manifest_path.display()))
    })?;
    let v = Value::parse(&text)
        .map_err(|e| IcrError::ArtifactCorrupt(format!("manifest is not valid JSON: {e}")))?;
    let schema = v
        .get("schema_version")
        .and_then(Value::as_usize)
        .ok_or_else(|| IcrError::ArtifactCorrupt("manifest missing \"schema_version\"".into()))?;
    if schema as u64 > SCHEMA_VERSION {
        return Err(IcrError::Unsupported(format!(
            "artifact schema_version {schema} is newer than supported {SCHEMA_VERSION}"
        )));
    }
    let name = manifest_str(&v, "name")?.to_string();
    let backend = Backend::parse(manifest_str(&v, "backend")?)
        .map_err(|e| IcrError::ArtifactCorrupt(format!("{e:#}")))?;
    let config_v = v
        .get("config")
        .ok_or_else(|| IcrError::ArtifactCorrupt("manifest missing \"config\"".into()))?;
    let config = ModelConfig::from_json(config_v);
    let declared = manifest_str(&v, "config_sha256")?.to_string();
    let actual = config_checksum(&config);
    if declared != actual {
        return Err(IcrError::ChecksumMismatch {
            what: "config checksum".into(),
            expected: declared,
            got: actual,
        });
    }
    let descriptor = ModelDescriptor::from_json(
        v.get("descriptor")
            .ok_or_else(|| IcrError::ArtifactCorrupt("manifest missing \"descriptor\"".into()))?,
    )
    .map_err(|e| IcrError::ArtifactCorrupt(format!("bad descriptor: {e}")))?;
    let provenance =
        Provenance::from_json(v.get("provenance").unwrap_or(&Value::Null));

    let entries = v
        .get("entries")
        .and_then(Value::as_array)
        .ok_or_else(|| IcrError::ArtifactCorrupt("manifest missing \"entries\"".into()))?;
    let mut domain = None;
    let mut obs = None;
    let mut posterior = None;
    for e in entries {
        let rel = manifest_str(e, "path")?;
        if rel.contains("..") || rel.contains('/') || rel.contains('\\') {
            return Err(IcrError::ArtifactCorrupt(format!(
                "entry path {rel:?} escapes the artifact directory"
            )));
        }
        let kind = manifest_str(e, "kind")?;
        let want_sha = manifest_str(e, "sha256")?;
        let want_len = e
            .get("len")
            .and_then(Value::as_usize)
            .ok_or_else(|| IcrError::ArtifactCorrupt(format!("entry {rel:?} missing \"len\"")))?;
        let path = dir.join(rel);
        let bytes = fs::read(&path)
            .map_err(|e| IcrError::ArtifactCorrupt(format!("read {}: {e}", path.display())))?;
        if bytes.len() != want_len {
            return Err(IcrError::ArtifactCorrupt(format!(
                "payload {rel:?} truncated: manifest says {want_len} bytes, file has {}",
                bytes.len()
            )));
        }
        let got_sha = sha256::hex_digest(&bytes);
        if got_sha != want_sha {
            return Err(IcrError::ChecksumMismatch {
                what: format!("payload {rel:?}"),
                expected: want_sha.to_string(),
                got: got_sha,
            });
        }
        let as_f64 = |bytes: &[u8]| {
            payload::decode_f64s(bytes)
                .map_err(|m| IcrError::ArtifactCorrupt(format!("payload {rel:?}: {m}")))
        };
        match kind {
            "domain_f64" => domain = Some(as_f64(&bytes)?),
            "obs_u64" => {
                obs = Some(payload::decode_u64s(&bytes).map_err(|m| {
                    IcrError::ArtifactCorrupt(format!("payload {rel:?}: {m}"))
                })?)
            }
            "posterior_f64" => posterior = Some(as_f64(&bytes)?),
            // Unknown payload kinds from newer writers are tolerated —
            // their digests verified above, their contents ignored.
            _ => {}
        }
    }
    let domain = domain
        .ok_or_else(|| IcrError::ArtifactCorrupt("artifact has no domain payload".into()))?;
    let obs =
        obs.ok_or_else(|| IcrError::ArtifactCorrupt("artifact has no obs payload".into()))?;
    if domain.len() != descriptor.n {
        return Err(IcrError::ArtifactCorrupt(format!(
            "domain payload has {} points, descriptor says n={}",
            domain.len(),
            descriptor.n
        )));
    }
    if let Some(xi) = &posterior {
        if xi.len() != descriptor.dof {
            return Err(IcrError::ArtifactCorrupt(format!(
                "posterior payload has {} values, descriptor says dof={}",
                xi.len(),
                descriptor.dof
            )));
        }
    }
    if let Some(&bad) = obs.iter().find(|&&i| i >= descriptor.n) {
        return Err(IcrError::ArtifactCorrupt(format!(
            "obs index {bad} out of range for n={}",
            descriptor.n
        )));
    }
    Ok(Snapshot { name, backend, config, descriptor, domain, obs, posterior, provenance })
}

/// One-stop load-and-rebuild: verify the artifact on disk, rebuild the
/// model from its config through [`crate::model::ModelBuilder`], and
/// assert bitwise geometry parity via [`Snapshot::verify_model`].
/// `aot_dir` is the AOT HLO artifact directory the PJRT family needs;
/// `exec` optionally shares a worker pool.
pub fn load_model(
    dir: &Path,
    exec: Option<crate::parallel::Exec>,
    aot_dir: &str,
) -> Result<(Arc<dyn GpModel>, Snapshot), IcrError> {
    let snap = load(dir)?;
    let mut b = snap.builder().artifact_dir(aot_dir);
    if let Some(exec) = exec {
        b = b.exec(exec);
    }
    let model = b.build()?;
    snap.verify_model(model.as_ref())?;
    Ok((model, snap))
}

/// Resolve the manifest path for display purposes.
pub fn manifest_path(dir: &Path) -> PathBuf {
    dir.join(MANIFEST_FILE)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelBuilder;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "icr-artifact-{tag}-{}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    fn small_model() -> (Arc<dyn GpModel>, ModelConfig) {
        let b = ModelBuilder::new().windows(3, 2).levels(3).target_n(40);
        let cfg = b.config().clone();
        (b.build().unwrap(), cfg)
    }

    #[test]
    fn config_checksum_is_deterministic_and_config_sensitive() {
        let a = ModelConfig::default();
        let mut b = ModelConfig::default();
        assert_eq!(config_checksum(&a), config_checksum(&b));
        b.target_n = a.target_n + 1;
        assert_ne!(config_checksum(&a), config_checksum(&b));
    }

    #[test]
    fn save_load_round_trip_preserves_everything() {
        let dir = tmp_dir("roundtrip");
        let (model, cfg) = small_model();
        let posterior = Some(vec![0.25; model.total_dof()]);
        let snap = Snapshot::capture("default", Backend::Native, &cfg, model.as_ref(), posterior, 2)
            .unwrap();
        save(&dir, &snap).unwrap();
        let back = load(&dir).unwrap();
        assert_eq!(back.name, "default");
        assert_eq!(back.backend, Backend::Native);
        assert_eq!(back.config, cfg);
        assert_eq!(back.descriptor, snap.descriptor);
        assert_eq!(back.domain, snap.domain);
        assert_eq!(back.obs, snap.obs);
        assert_eq!(back.posterior, snap.posterior);
        assert_eq!(back.provenance.version, crate::VERSION);
        assert_eq!(back.provenance.apply_threads, 2);
        back.verify_model(model.as_ref()).unwrap();
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn remote_proxies_cannot_be_snapshotted() {
        let (model, cfg) = small_model();
        match Snapshot::capture("d", Backend::Remote, &cfg, model.as_ref(), None, 0) {
            Err(IcrError::Unsupported(m)) => assert!(m.contains("remote"), "{m}"),
            other => panic!("expected unsupported, got {other:?}"),
        }
    }

    #[test]
    fn payload_byte_flip_is_rejected_with_checksum_mismatch() {
        let dir = tmp_dir("byteflip");
        let (model, cfg) = small_model();
        let snap =
            Snapshot::capture("default", Backend::Native, &cfg, model.as_ref(), None, 0).unwrap();
        save(&dir, &snap).unwrap();
        let path = dir.join("domain.bin");
        let mut bytes = fs::read(&path).unwrap();
        bytes[3] ^= 0x40;
        fs::write(&path, &bytes).unwrap();
        match load(&dir) {
            Err(IcrError::ChecksumMismatch { what, .. }) => {
                assert!(what.contains("domain.bin"), "{what}")
            }
            other => panic!("expected checksum mismatch, got {:?}", other.map(|s| s.name)),
        }
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn truncated_payload_is_rejected_as_corrupt() {
        let dir = tmp_dir("truncate");
        let (model, cfg) = small_model();
        let snap =
            Snapshot::capture("default", Backend::Native, &cfg, model.as_ref(), None, 0).unwrap();
        save(&dir, &snap).unwrap();
        let path = dir.join("obs.bin");
        let bytes = fs::read(&path).unwrap();
        fs::write(&path, &bytes[..bytes.len() - 8]).unwrap();
        match load(&dir) {
            Err(IcrError::ArtifactCorrupt(m)) => assert!(m.contains("truncated"), "{m}"),
            other => panic!("expected corrupt, got {:?}", other.map(|s| s.name)),
        }
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn tampered_config_is_rejected_by_the_config_checksum() {
        let dir = tmp_dir("tamper");
        let (model, cfg) = small_model();
        let snap =
            Snapshot::capture("default", Backend::Native, &cfg, model.as_ref(), None, 0).unwrap();
        save(&dir, &snap).unwrap();
        let path = manifest_path(&dir);
        let text = fs::read_to_string(&path).unwrap();
        // Change the config without refreshing config_sha256.
        let tampered = text.replace("\"target_n\": 40", "\"target_n\": 41");
        assert_ne!(tampered, text, "tamper target not found");
        fs::write(&path, tampered).unwrap();
        match load(&dir) {
            Err(IcrError::ChecksumMismatch { what, .. }) => {
                assert!(what.contains("config"), "{what}")
            }
            other => panic!("expected checksum mismatch, got {:?}", other.map(|s| s.name)),
        }
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn garbage_manifest_is_rejected_as_corrupt() {
        let dir = tmp_dir("garbage");
        fs::create_dir_all(&dir).unwrap();
        fs::write(manifest_path(&dir), b"{not json").unwrap();
        assert!(matches!(load(&dir), Err(IcrError::ArtifactCorrupt(_))));
        fs::write(manifest_path(&dir), b"{\"schema_version\": 99}").unwrap();
        assert!(matches!(load(&dir), Err(IcrError::Unsupported(_))));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn entry_paths_cannot_escape_the_directory() {
        let dir = tmp_dir("escape");
        let (model, cfg) = small_model();
        let snap =
            Snapshot::capture("default", Backend::Native, &cfg, model.as_ref(), None, 0).unwrap();
        save(&dir, &snap).unwrap();
        let path = manifest_path(&dir);
        let text = fs::read_to_string(&path).unwrap();
        fs::write(&path, text.replace("domain.bin", "../domain.bin")).unwrap();
        match load(&dir) {
            Err(IcrError::ArtifactCorrupt(m)) => assert!(m.contains("escapes"), "{m}"),
            other => panic!("expected corrupt, got {:?}", other.map(|s| s.name)),
        }
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn load_model_rebuilds_with_bitwise_sample_parity() {
        let dir = tmp_dir("rebuild");
        let (model, cfg) = small_model();
        let snap =
            Snapshot::capture("default", Backend::Native, &cfg, model.as_ref(), None, 0).unwrap();
        save(&dir, &snap).unwrap();
        let (loaded, back) = load_model(&dir, None, "artifacts").unwrap();
        assert_eq!(back.descriptor, model.descriptor());
        assert_eq!(loaded.sample(3, 77).unwrap(), model.sample(3, 77).unwrap());
        let _ = fs::remove_dir_all(&dir);
    }
}
