//! Little-endian binary payload codec for artifact entries.
//!
//! Payloads are raw fixed-width arrays — `f64` for domain points and
//! excitations, `u64` for observation indices — with no framing of their
//! own: lengths and integrity live in `manifest.json` (`len`, `sha256`
//! per entry), mirroring the AOT manifest+payload split the runtime uses
//! for HLO artifacts.

/// Encode a slice of `f64` as little-endian bytes (8 per value).
pub fn encode_f64s(values: &[f64]) -> Vec<u8> {
    let mut out = Vec::with_capacity(values.len() * 8);
    for v in values {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// Decode little-endian `f64` bytes; rejects lengths that are not a
/// multiple of 8.
pub fn decode_f64s(bytes: &[u8]) -> Result<Vec<f64>, String> {
    if bytes.len() % 8 != 0 {
        return Err(format!("payload length {} is not a multiple of 8", bytes.len()));
    }
    Ok(bytes
        .chunks_exact(8)
        .map(|c| f64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]))
        .collect())
}

/// Encode a slice of `usize` as little-endian `u64` bytes.
pub fn encode_u64s(values: &[usize]) -> Vec<u8> {
    let mut out = Vec::with_capacity(values.len() * 8);
    for v in values {
        out.extend_from_slice(&(*v as u64).to_le_bytes());
    }
    out
}

/// Decode little-endian `u64` bytes into `usize` indices.
pub fn decode_u64s(bytes: &[u8]) -> Result<Vec<usize>, String> {
    if bytes.len() % 8 != 0 {
        return Err(format!("payload length {} is not a multiple of 8", bytes.len()));
    }
    bytes
        .chunks_exact(8)
        .map(|c| {
            let v = u64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]);
            usize::try_from(v).map_err(|_| format!("index {v} exceeds usize"))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f64_round_trip_is_bitwise() {
        let vals = [0.0, -0.0, 1.5, f64::MIN_POSITIVE, 1e308, -3.25, f64::INFINITY];
        let back = decode_f64s(&encode_f64s(&vals)).unwrap();
        assert_eq!(back.len(), vals.len());
        for (a, b) in vals.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn u64_round_trip() {
        let vals = [0usize, 1, 2, 1 << 40, usize::MAX];
        assert_eq!(decode_u64s(&encode_u64s(&vals)).unwrap(), vals);
    }

    #[test]
    fn truncated_payloads_are_rejected() {
        assert!(decode_f64s(&[0u8; 7]).is_err());
        assert!(decode_u64s(&[0u8; 9]).is_err());
    }
}
