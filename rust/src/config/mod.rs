//! Typed configuration system.
//!
//! Configuration is layered, highest priority last:
//! 1. built-in defaults,
//! 2. a JSON config file (`--config path.json`),
//! 3. CLI flags.
//!
//! The same [`ModelConfig`] drives the native engine, the PJRT engine and
//! the experiment drivers, so a run is fully reproducible from its config
//! dump (`icr serve --dump-config`).

use std::path::Path;

use anyhow::{Context, Result};

use crate::chart::{parse_chart, Chart};
use crate::cli::Args;
use crate::icr::RefinementParams;
use crate::json::{self, Value};
use crate::kernels::{parse_kernel, Kernel};
use crate::net::{IoMode, ListenAddr, RoutePolicy};

/// Engine families a registry entry can run on, advertised by
/// `icr --version` and the `stats` document (`model_families`).
pub const MODEL_FAMILIES: [&str; 5] = ["native", "pjrt", "kissgp", "exact", "remote"];

/// Which engine family executes a model's applies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Rust-native ICR engine (no artifacts needed).
    Native,
    /// AOT-compiled XLA executables via PJRT.
    Pjrt,
    /// KISS-GP baseline (circulant spectral square root).
    Kissgp,
    /// Exact dense reference (Cholesky square root, O(N³) build).
    Exact,
    /// Remote coordinator reached over the cluster tcp client; the
    /// address travels separately (`ModelSpec::remote` /
    /// `MemberSpec::remote`, spelled `remote:tcp:HOST:PORT`).
    Remote,
}

impl Backend {
    pub fn parse(s: &str) -> Result<Backend> {
        match s {
            "native" => Ok(Backend::Native),
            "pjrt" | "xla" => Ok(Backend::Pjrt),
            "kissgp" | "kiss" => Ok(Backend::Kissgp),
            "exact" | "dense" => Ok(Backend::Exact),
            "remote" => Ok(Backend::Remote),
            other => anyhow::bail!("unknown backend {other:?} (native|pjrt|kissgp|exact|remote)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Backend::Native => "native",
            Backend::Pjrt => "pjrt",
            Backend::Kissgp => "kissgp",
            Backend::Exact => "exact",
            Backend::Remote => "remote",
        }
    }
}

/// Split a `remote:tcp:HOST:PORT` backend value into the family and the
/// validated remote address (`tcp:HOST:PORT`); plain family names pass
/// through with no address.
fn parse_backend_value(s: &str) -> Result<(Backend, Option<String>)> {
    let s = s.trim();
    match s.strip_prefix("remote:") {
        Some(addr) => Ok((Backend::Remote, Some(validate_remote_addr(addr)?))),
        None => Ok((Backend::parse(s)?, None)),
    }
}

/// Validate a remote member address: `tcp:HOST:PORT`. The single
/// grammar check shared by the config parsers and the cluster client —
/// keep CLI-accepted and client-accepted addresses identical.
pub(crate) fn validate_remote_addr(addr: &str) -> Result<String> {
    let addr = addr.trim();
    let hostport = addr
        .strip_prefix("tcp:")
        .ok_or_else(|| anyhow::anyhow!("remote address {addr:?} must be tcp:HOST:PORT"))?;
    anyhow::ensure!(
        hostport.rsplit_once(':').map(|(h, p)| !h.is_empty() && p.parse::<u16>().is_ok())
            == Some(true),
        "remote address {addr:?} must be tcp:HOST:PORT"
    );
    Ok(addr.to_string())
}

/// The GP model: kernel + chart + refinement geometry.
#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub kernel_spec: String,
    pub chart_spec: String,
    pub n_csz: usize,
    pub n_fsz: usize,
    pub n_lvl: usize,
    /// Target number of modeled points (base grid derived via
    /// [`RefinementParams::for_target`]).
    pub target_n: usize,
}

impl Default for ModelConfig {
    fn default() -> Self {
        // The paper's §5.1 optimum: (5,4), n_lvl = 5, N ≈ 200, Matérn-3/2,
        // log-spaced points spanning two orders of magnitude in spacing.
        ModelConfig {
            kernel_spec: "matern32(rho=1.0, amp=1.0)".into(),
            chart_spec: "paper_log".into(),
            n_csz: 5,
            n_fsz: 4,
            n_lvl: 5,
            target_n: 200,
        }
    }
}

impl ModelConfig {
    pub fn refinement_params(&self) -> Result<RefinementParams> {
        RefinementParams::for_target(self.n_csz, self.n_fsz, self.n_lvl, self.target_n)
    }

    pub fn kernel(&self) -> Result<Box<dyn Kernel>> {
        parse_kernel(&self.kernel_spec).map_err(|e| anyhow::anyhow!(e))
    }

    /// Build the chart. `paper_log` is resolved against the final grid of
    /// this config's geometry (the §5.1 construction: nn distances from
    /// 2%·ρ to ρ across the modeled points).
    pub fn chart(&self) -> Result<Box<dyn Chart>> {
        if self.chart_spec == "paper_log" {
            let params = self.refinement_params()?;
            let geo = crate::icr::Geometry::build(params);
            let fin = geo.final_positions();
            let n = fin.len();
            let rho = self.kernel()?.lengthscale();
            let beta = (1.0_f64 / 0.02).ln() / (n as f64 - 2.0);
            let alpha = (0.02 * rho / (beta.exp() - 1.0)).ln() - beta * fin[0];
            return Ok(Box::new(crate::chart::LogChart::new(alpha, beta)));
        }
        parse_chart(&self.chart_spec).map_err(|e| anyhow::anyhow!(e))
    }

    /// Decode a config from its canonical JSON object (the inverse of
    /// [`Self::to_json`]); absent keys keep their defaults. Artifact
    /// manifests round-trip model configs through this pair.
    pub fn from_json(v: &Value) -> ModelConfig {
        let mut cfg = ModelConfig::default();
        cfg.apply_json(v);
        cfg
    }

    fn apply_json(&mut self, v: &Value) {
        if let Some(s) = v.get("kernel").and_then(Value::as_str) {
            self.kernel_spec = s.to_string();
        }
        if let Some(s) = v.get("chart").and_then(Value::as_str) {
            self.chart_spec = s.to_string();
        }
        if let Some(x) = v.get("n_csz").and_then(Value::as_usize) {
            self.n_csz = x;
        }
        if let Some(x) = v.get("n_fsz").and_then(Value::as_usize) {
            self.n_fsz = x;
        }
        if let Some(x) = v.get("n_lvl").and_then(Value::as_usize) {
            self.n_lvl = x;
        }
        if let Some(x) = v.get("target_n").and_then(Value::as_usize) {
            self.target_n = x;
        }
    }

    fn apply_args(&mut self, args: &Args) -> Result<()> {
        if let Some(k) = args.get("kernel") {
            self.kernel_spec = k.to_string();
        }
        if let Some(c) = args.get("chart") {
            self.chart_spec = c.to_string();
        }
        self.n_csz = args.get_usize("csz", self.n_csz)?;
        self.n_fsz = args.get_usize("fsz", self.n_fsz)?;
        self.n_lvl = args.get_usize("lvl", self.n_lvl)?;
        self.target_n = args.get_usize("n", self.target_n)?;
        Ok(())
    }

    /// Modeled locations in the domain 𝒟: the chart image of the final
    /// refinement grid. Every engine family of this config models these
    /// same points, which is what makes cross-model serving comparable.
    pub fn domain_points(&self) -> Result<Vec<f64>> {
        let params = self.refinement_params()?;
        let geo = crate::icr::Geometry::build(params);
        let chart = self.chart()?;
        Ok(geo.final_positions().iter().map(|&u| chart.to_domain(u)).collect())
    }

    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("kernel", json::s(&self.kernel_spec)),
            ("chart", json::s(&self.chart_spec)),
            ("n_csz", json::num(self.n_csz as f64)),
            ("n_fsz", json::num(self.n_fsz as f64)),
            ("n_lvl", json::num(self.n_lvl as f64)),
            ("target_n", json::num(self.target_n as f64)),
        ])
    }
}

/// The name under which the coordinator's primary model is registered,
/// and the model v1 (untagged) protocol frames route to.
pub const DEFAULT_MODEL_NAME: &str = "default";

/// A named model hosted by the coordinator: registry key + engine family
/// + model configuration. Remote entries (`Backend::Remote`) carry the
/// backend coordinator's address in `remote`.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub name: String,
    pub backend: Backend,
    pub model: ModelConfig,
    /// `Some("tcp:HOST:PORT")` for `Backend::Remote` entries.
    pub remote: Option<String>,
}

impl ModelSpec {
    /// An in-process entry (every family except `Backend::Remote`).
    pub fn local(name: &str, backend: Backend, model: ModelConfig) -> ModelSpec {
        ModelSpec { name: name.to_string(), backend, model, remote: None }
    }

    pub fn to_json(&self) -> Value {
        let mut fields = vec![
            ("name", json::s(&self.name)),
            ("backend", json::s(self.backend.name())),
            ("model", self.model.to_json()),
        ];
        if let Some(addr) = &self.remote {
            fields.push(("remote", json::s(addr)));
        }
        json::obj(fields)
    }
}

/// One member of a replica set: an in-process engine family, or a remote
/// coordinator reached over the cluster tcp client.
#[derive(Debug, Clone, PartialEq)]
pub struct MemberSpec {
    pub backend: Backend,
    /// `Some("tcp:HOST:PORT")` when `backend == Backend::Remote`.
    pub remote: Option<String>,
}

impl MemberSpec {
    pub fn local(backend: Backend) -> MemberSpec {
        MemberSpec { backend, remote: None }
    }

    pub fn remote(addr: &str) -> Result<MemberSpec> {
        Ok(MemberSpec { backend: Backend::Remote, remote: Some(validate_remote_addr(addr)?) })
    }

    /// Parse one member run: `native` / `exact:2` expand to `count`
    /// identical local members; `remote:tcp:HOST:PORT` is one remote
    /// member.
    pub fn parse_run(s: &str) -> Result<Vec<MemberSpec>> {
        let s = s.trim();
        if let Some(addr) = s.strip_prefix("remote:") {
            return Ok(vec![MemberSpec::remote(addr)?]);
        }
        let (backend, count) = match s.split_once(':') {
            Some((b, c)) => {
                let count: usize = c
                    .trim()
                    .parse()
                    .map_err(|e| anyhow::anyhow!("member spec {s:?}: bad count: {e}"))?;
                (Backend::parse(b.trim())?, count)
            }
            None => (Backend::parse(s)?, 1),
        };
        anyhow::ensure!(count >= 1, "member spec {s:?} needs count >= 1");
        anyhow::ensure!(
            backend != Backend::Remote,
            "member spec {s:?}: remote members need an address (remote:tcp:HOST:PORT)"
        );
        Ok(vec![MemberSpec::local(backend); count])
    }

    /// The spec string this member parses back from (`native`,
    /// `remote:tcp:HOST:PORT`).
    pub fn spec_string(&self) -> String {
        match &self.remote {
            Some(addr) => format!("remote:{addr}"),
            None => self.backend.name().to_string(),
        }
    }
}

/// A replica set declaration: an ordered member list registered as
/// `{name}@0..{name}@k-1`, every local member built from the server's
/// base model and sharing the coordinator's one worker pool, remote
/// members proxied to their backend coordinator. Requests addressed to
/// the logical `name` are routed across the members by the configured
/// [`RoutePolicy`] (`DESIGN.md` §8/§9).
#[derive(Debug, Clone, PartialEq)]
pub struct ReplicaSpec {
    pub name: String,
    pub members: Vec<MemberSpec>,
}

impl ReplicaSpec {
    /// Validated constructor — the one path every replica declaration
    /// (CLI or config file) goes through, enforcing the `@` reservation
    /// for member names.
    pub fn new(name: &str, members: Vec<MemberSpec>) -> Result<ReplicaSpec> {
        let name = name.trim();
        anyhow::ensure!(!name.is_empty(), "replica set name may not be empty");
        anyhow::ensure!(
            !name.contains('@'),
            "replica set name {name:?} may not contain '@' (reserved for member names)"
        );
        anyhow::ensure!(!members.is_empty(), "replica set {name:?} needs at least one member");
        Ok(ReplicaSpec { name: name.to_string(), members })
    }

    /// `count` identical local members on one backend — the pre-cluster
    /// `gp=native:3` shape.
    pub fn homogeneous(name: &str, backend: Backend, count: usize) -> Result<ReplicaSpec> {
        anyhow::ensure!(count >= 1, "replica set {name:?} needs count >= 1");
        Self::new(name, vec![MemberSpec::local(backend); count])
    }

    /// Parse the full `--replicas` list. Comma-separated pieces:
    /// `name=RUN` starts a set, bare `RUN` pieces extend the most recent
    /// one, so `gp=native:2,remote:tcp:h1:7777,remote:tcp:h2:7777` is one
    /// four-member† set and `gp=native:3,ref=exact` stays two sets.
    /// († two local + two remote members.)
    pub fn parse_list(list: &str) -> Result<Vec<ReplicaSpec>> {
        let mut sets: Vec<(String, Vec<MemberSpec>)> = Vec::new();
        for piece in list.split(',').filter(|p| !p.trim().is_empty()) {
            let piece = piece.trim();
            match piece.split_once('=') {
                Some((name, run)) => {
                    let members = MemberSpec::parse_run(run)
                        .with_context(|| format!("--replicas entry {piece:?}"))?;
                    sets.push((name.to_string(), members));
                }
                None => match sets.last_mut() {
                    Some((_, members)) => members.extend(
                        MemberSpec::parse_run(piece)
                            .with_context(|| format!("--replicas entry {piece:?}"))?,
                    ),
                    None => anyhow::bail!(
                        "--replicas entry {piece:?} extends no set (start with name=backend[:count])"
                    ),
                },
            }
        }
        sets.into_iter()
            .map(|(name, members)| {
                ReplicaSpec::new(&name, members)
                    .with_context(|| format!("--replicas set {name:?}"))
            })
            .collect()
    }

    /// Number of members.
    pub fn count(&self) -> usize {
        self.members.len()
    }

    /// Registry entry names of the members, in routing order.
    pub fn member_names(&self) -> Vec<String> {
        (0..self.members.len()).map(|i| format!("{}@{i}", self.name)).collect()
    }

    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("name", json::s(&self.name)),
            (
                "members",
                json::arr(self.members.iter().map(|m| json::s(&m.spec_string())).collect()),
            ),
        ])
    }
}

/// The coordinator/server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Configuration of the default model (v1 behavior; registered under
    /// [`DEFAULT_MODEL_NAME`]).
    pub model: ModelConfig,
    /// Engine family of the default model.
    pub backend: Backend,
    /// Additional named models hosted alongside the default one. Protocol
    /// v2 requests route by the `model` field of the frame.
    pub extra_models: Vec<ModelSpec>,
    pub workers: usize,
    /// Maximum applies coalesced into one micro-batch (`--batch-max`;
    /// `--max-batch` is the legacy spelling): the size flush threshold
    /// of the batching window (`DESIGN.md` §11).
    pub max_batch: usize,
    /// Micro-batch window in µs (`--batch-window-us`; `--max-wait-us`
    /// is the legacy spelling): how long past the *first* request's
    /// enqueue the batcher holds a partial batch open for stragglers
    /// before the deadline flush.
    pub max_wait_us: u64,
    /// Worker-pool lanes per batched `√K` panel apply (`--apply-threads`;
    /// `0` = one per available core). The coordinator builds one
    /// persistent pool of this width and shares it across every hosted
    /// model. Outputs are bit-identical at every setting — the knob
    /// trades per-request latency against worker parallelism
    /// (`DESIGN.md` §6/§7). Defaults to `ICR_APPLY_THREADS` when set.
    pub apply_threads: usize,
    pub artifact_dir: String,
    pub seed: u64,
    /// Where `icr serve` listens (`--listen stdio|tcp:HOST:PORT|unix:PATH`,
    /// default stdio — the legacy loop, byte-identical).
    pub listen: ListenAddr,
    /// Concurrent-connection cap for socket transports; connections
    /// beyond it are refused with a typed `overloaded` frame.
    pub max_connections: usize,
    /// Close a connection with nothing in flight after this long
    /// (`--idle-timeout-ms`, 0 disables).
    pub idle_timeout_ms: u64,
    /// Bound on the coordinator's request queue (`--queue-limit`, 0 =
    /// unbounded). When full, submits answer immediately with a typed
    /// `overloaded` error instead of queueing — the backpressure signal
    /// socket sessions forward to their clients.
    pub queue_limit: usize,
    /// Replica sets over the registry (`--replicas gp=native:3` or mixed
    /// local+remote: `gp=native:2,remote:tcp:h1:7777,remote:tcp:h2:7777`).
    pub replicas: Vec<ReplicaSpec>,
    /// How replica sets pick members (`--route-policy`).
    pub route_policy: RoutePolicy,
    /// Bound on the response cache for deterministic sample requests
    /// (`--cache-entries`, 0 = disabled — the default, so cacheless
    /// serving is byte-identical to the pre-cluster behavior).
    pub cache_entries: usize,
    /// Replica-member health-probe period (`--health-interval-ms`, 0
    /// disables the monitor). A member failing its probe is ejected from
    /// routing within one interval and restored when the probe recovers.
    pub health_interval_ms: u64,
    /// How socket connections are hosted (`--io-mode event|threads`,
    /// `DESIGN.md` §11): `event` (default) runs every connection on one
    /// epoll/poll readiness loop; `threads` keeps the legacy
    /// reader+writer thread pair per connection — the §8 baseline the
    /// `connections_scaling` bench compares against.
    pub io_mode: IoMode,
    /// Blocking-reader poll granularity in ms (`--io-poll-ms`): how
    /// often a threads-mode session reader wakes to re-check the drain
    /// flag and idle deadline. Only the blocking paths (threads mode,
    /// stdio) poll; the event loop sleeps on readiness instead.
    pub io_poll_ms: u64,
    /// Request-level circuit breaker (`DESIGN.md` §12): sliding window
    /// of recent request outcomes per replica member
    /// (`--breaker-window`, 0 disables breakers).
    pub breaker_window: usize,
    /// Failure ratio within a full window that trips a member's breaker
    /// Closed → Open (`--breaker-trip-ratio`).
    pub breaker_trip_ratio: f64,
    /// How long a tripped member stays Open before Half-Open trial
    /// requests are admitted (`--breaker-cooldown-ms`).
    pub breaker_cooldown_ms: u64,
    /// Failover attempts after the first failure of an idempotent
    /// routed request (`--retry-max`, 0 disables retry/failover).
    pub retry_max: usize,
    /// Deadline budget per routed request in ms, anchored at enqueue
    /// (`--retry-budget-ms`): retries stop once the budget is spent and
    /// the client receives a typed `retry_exhausted` error.
    pub retry_budget_ms: u64,
    /// Remote data-call timeout in ms (`--remote-call-timeout-ms`).
    pub remote_call_timeout_ms: u64,
    /// Remote health-probe timeout in ms (`--remote-probe-timeout-ms`).
    pub remote_probe_timeout_ms: u64,
    /// Remote connect timeout in ms (`--remote-connect-timeout-ms`).
    pub remote_connect_timeout_ms: u64,
    /// Deterministic fault injection spec (`--fault-inject
    /// "remote:error=0.1,delay_ms=50,drop=0.02"`, `DESIGN.md` §12), or
    /// the `ICR_FAULT_INJECT` env var when the flag is absent. `None`
    /// (default) disarms the harness entirely.
    pub fault_inject: Option<String>,
    /// Head-sampling probability for request traces
    /// (`--trace-sample-rate`, in [0, 1]; 0 disables background
    /// sampling — explicit `"trace": true` requests are still traced).
    pub trace_sample_rate: f64,
    /// Requests slower than this always commit a trace and emit a
    /// structured `slow_request` event (`--trace-slow-ms`, 0 disables
    /// slow detection).
    pub trace_slow_ms: u64,
    /// Structured-log severity floor (`--log-level
    /// error|warn|info|debug`, also `off`).
    pub log_level: String,
    /// Structured-log rendering (`--log-format json|text`).
    pub log_format: String,
    /// Structured-log destination (`--log-dest stderr|file:PATH`).
    pub log_dest: String,
    /// Prometheus scrape endpoint (`--metrics-listen tcp:HOST:PORT`,
    /// DESIGN.md §13); `None` (default) serves no endpoint.
    pub metrics_listen: Option<String>,
    /// Rotate a `file:` log destination once it exceeds this many bytes
    /// (`--log-rotate-bytes`, 0 = never rotate, the default).
    pub log_rotate_bytes: u64,
    /// Rotated generations to keep (`--log-rotate-keep`, ≥ 1):
    /// `PATH.1` (newest) through `PATH.{keep}` (oldest).
    pub log_rotate_keep: usize,
    /// Arm the sampling phase profiler at boot (`--profile`,
    /// DESIGN.md §14): an unbounded collection run controllable (and
    /// dumpable) via the protocol-v2 `profile` op.
    pub profile: bool,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            model: ModelConfig::default(),
            backend: Backend::Native,
            extra_models: Vec::new(),
            workers: 2,
            max_batch: 8,
            max_wait_us: 200,
            apply_threads: crate::parallel::default_apply_threads(),
            artifact_dir: "artifacts".into(),
            seed: 0xED40FE5,
            listen: ListenAddr::Stdio,
            max_connections: 64,
            idle_timeout_ms: 300_000,
            queue_limit: 0,
            replicas: Vec::new(),
            route_policy: RoutePolicy::default(),
            cache_entries: 0,
            health_interval_ms: 2000,
            io_mode: IoMode::default(),
            io_poll_ms: 25,
            breaker_window: 16,
            breaker_trip_ratio: 0.5,
            breaker_cooldown_ms: 1000,
            retry_max: 2,
            retry_budget_ms: 10_000,
            remote_call_timeout_ms: 120_000,
            remote_probe_timeout_ms: 2_000,
            remote_connect_timeout_ms: 5_000,
            fault_inject: None,
            trace_sample_rate: 0.0,
            trace_slow_ms: 0,
            log_level: "info".into(),
            log_format: "json".into(),
            log_dest: "stderr".into(),
            metrics_listen: None,
            log_rotate_bytes: 0,
            log_rotate_keep: crate::obs::log::DEFAULT_LOG_ROTATE_KEEP,
            profile: false,
        }
    }
}

impl ServerConfig {
    /// Defaults ← JSON file (if given) ← CLI flags.
    pub fn resolve(args: &Args) -> Result<ServerConfig> {
        let mut cfg = ServerConfig::default();
        if let Some(path) = args.get("config") {
            cfg.apply_file(Path::new(path))
                .with_context(|| format!("loading config file {path}"))?;
        }
        cfg.model.apply_args(args)?;
        if let Some(b) = args.get("backend") {
            cfg.backend = Backend::parse(b)?;
        }
        if args.get("models").is_none() {
            // Re-materialize file-declared extras on top of the
            // CLI-finalized base model: apply_file ran before the CLI
            // overrides, and extras must share the final geometry.
            if let Some(path) = args.get("config") {
                let text = std::fs::read_to_string(path)
                    .with_context(|| format!("re-reading config file {path}"))?;
                let v = Value::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
                cfg.apply_models_json(&v)?;
            }
        }
        if let Some(list) = args.get("models") {
            // `--models kiss=kissgp,ref=exact,gp=remote:tcp:h:7777`: extra
            // named models sharing the default model's geometry/kernel
            // but each on its own engine family — or proxied to a remote
            // coordinator (the quick path to a multi-model server; the
            // config file's `models` array allows full per-model configs).
            cfg.extra_models = list
                .split(',')
                .filter(|p| !p.trim().is_empty())
                .map(|pair| -> Result<ModelSpec> {
                    let (name, backend) = pair
                        .trim()
                        .split_once('=')
                        .ok_or_else(|| anyhow::anyhow!("--models entry {pair:?} is not name=backend"))?;
                    anyhow::ensure!(!name.trim().is_empty(), "--models entry {pair:?} has empty name");
                    let (backend, remote) = parse_backend_value(backend)
                        .with_context(|| format!("--models entry {pair:?}"))?;
                    Ok(ModelSpec {
                        name: name.trim().to_string(),
                        backend,
                        model: cfg.model.clone(),
                        remote,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
        }
        cfg.workers = args.get_usize("workers", cfg.workers)?.max(1);
        cfg.max_batch = args.get_usize("max-batch", cfg.max_batch)?.max(1);
        cfg.max_wait_us = args.get_u64("max-wait-us", cfg.max_wait_us)?;
        // Micro-batch spellings (`DESIGN.md` §11); they win over the
        // legacy --max-batch/--max-wait-us aliases above when both are
        // given.
        cfg.max_batch = args.get_usize("batch-max", cfg.max_batch)?.max(1);
        cfg.max_wait_us = args.get_u64("batch-window-us", cfg.max_wait_us)?;
        cfg.apply_threads = args.get_usize("apply-threads", cfg.apply_threads)?;
        if let Some(d) = args.get("artifacts") {
            cfg.artifact_dir = d.to_string();
        }
        cfg.seed = args.get_u64("seed", cfg.seed)?;
        if let Some(l) = args.get("listen") {
            cfg.listen = ListenAddr::parse(l).map_err(|e| anyhow::anyhow!(e))?;
        }
        cfg.max_connections = args.get_usize("max-connections", cfg.max_connections)?.max(1);
        cfg.idle_timeout_ms = args.get_u64("idle-timeout-ms", cfg.idle_timeout_ms)?;
        cfg.queue_limit = args.get_usize("queue-limit", cfg.queue_limit)?;
        if let Some(m) = args.get("io-mode") {
            cfg.io_mode = IoMode::parse(m).map_err(|e| anyhow::anyhow!(e))?;
        }
        cfg.io_poll_ms = args.get_u64("io-poll-ms", cfg.io_poll_ms)?.max(1);
        if let Some(list) = args.get("replicas") {
            cfg.replicas = ReplicaSpec::parse_list(list)?;
        }
        if let Some(p) = args.get("route-policy") {
            cfg.route_policy = RoutePolicy::parse(p).map_err(|e| anyhow::anyhow!(e))?;
        }
        cfg.cache_entries = args.get_usize("cache-entries", cfg.cache_entries)?;
        cfg.health_interval_ms = args.get_u64("health-interval-ms", cfg.health_interval_ms)?;
        cfg.breaker_window = args.get_usize("breaker-window", cfg.breaker_window)?;
        cfg.breaker_trip_ratio = args.get_f64("breaker-trip-ratio", cfg.breaker_trip_ratio)?;
        anyhow::ensure!(
            cfg.breaker_trip_ratio > 0.0 && cfg.breaker_trip_ratio <= 1.0,
            "--breaker-trip-ratio must be in (0, 1], got {}",
            cfg.breaker_trip_ratio
        );
        cfg.breaker_cooldown_ms = args.get_u64("breaker-cooldown-ms", cfg.breaker_cooldown_ms)?;
        cfg.retry_max = args.get_usize("retry-max", cfg.retry_max)?;
        cfg.retry_budget_ms = args.get_u64("retry-budget-ms", cfg.retry_budget_ms)?;
        cfg.remote_call_timeout_ms =
            args.get_u64("remote-call-timeout-ms", cfg.remote_call_timeout_ms)?.max(1);
        cfg.remote_probe_timeout_ms =
            args.get_u64("remote-probe-timeout-ms", cfg.remote_probe_timeout_ms)?.max(1);
        cfg.remote_connect_timeout_ms =
            args.get_u64("remote-connect-timeout-ms", cfg.remote_connect_timeout_ms)?.max(1);
        if let Some(spec) = args.get("fault-inject") {
            cfg.fault_inject = Some(spec.to_string());
        } else if cfg.fault_inject.is_none() {
            if let Ok(spec) = std::env::var("ICR_FAULT_INJECT") {
                if !spec.trim().is_empty() {
                    cfg.fault_inject = Some(spec);
                }
            }
        }
        if let Some(spec) = &cfg.fault_inject {
            // Fail at startup, not mid-traffic: the grammar check is
            // shared with the cluster harness itself.
            crate::cluster::FaultPlan::parse(spec, cfg.seed)
                .map_err(|e| anyhow::anyhow!("--fault-inject: {e}"))?;
        }
        cfg.trace_sample_rate = args.get_f64("trace-sample-rate", cfg.trace_sample_rate)?;
        anyhow::ensure!(
            (0.0..=1.0).contains(&cfg.trace_sample_rate),
            "--trace-sample-rate must be in [0, 1], got {}",
            cfg.trace_sample_rate
        );
        cfg.trace_slow_ms = args.get_u64("trace-slow-ms", cfg.trace_slow_ms)?;
        if let Some(l) = args.get("log-level") {
            cfg.log_level = l.to_string();
        }
        anyhow::ensure!(
            crate::obs::Level::parse(&cfg.log_level).is_some(),
            "--log-level must be off|error|warn|info|debug, got {:?}",
            cfg.log_level
        );
        if let Some(f) = args.get("log-format") {
            cfg.log_format = f.to_string();
        }
        anyhow::ensure!(
            crate::obs::LogFormat::parse(&cfg.log_format).is_some(),
            "--log-format must be json|text, got {:?}",
            cfg.log_format
        );
        if let Some(d) = args.get("log-dest") {
            cfg.log_dest = d.to_string();
        }
        anyhow::ensure!(
            crate::obs::LogDest::parse(&cfg.log_dest).is_some(),
            "--log-dest must be stderr|file:PATH, got {:?}",
            cfg.log_dest
        );
        if let Some(m) = args.get("metrics-listen") {
            cfg.metrics_listen = Some(m.to_string());
        }
        if let Some(m) = &cfg.metrics_listen {
            // Scrape endpoints are TCP sockets, never stdio/unix.
            match ListenAddr::parse(m) {
                Ok(ListenAddr::Tcp(_)) => {}
                Ok(_) => anyhow::bail!("--metrics-listen must be tcp:HOST:PORT, got {m:?}"),
                Err(e) => anyhow::bail!("--metrics-listen: {e}"),
            }
        }
        cfg.log_rotate_bytes = args.get_u64("log-rotate-bytes", cfg.log_rotate_bytes)?;
        cfg.log_rotate_keep = args.get_usize("log-rotate-keep", cfg.log_rotate_keep)?;
        anyhow::ensure!(
            cfg.log_rotate_keep >= 1,
            "--log-rotate-keep must be >= 1, got {}",
            cfg.log_rotate_keep
        );
        if args.has_switch("profile") {
            cfg.profile = true;
        }
        cfg.validate_models()?;
        Ok(cfg)
    }

    /// The full registry: the default model first, then the extras.
    pub fn model_specs(&self) -> Vec<ModelSpec> {
        let mut specs =
            vec![ModelSpec::local(DEFAULT_MODEL_NAME, self.backend, self.model.clone())];
        specs.extend(self.extra_models.iter().cloned());
        specs
    }

    fn validate_models(&self) -> Result<()> {
        let mut seen = std::collections::BTreeSet::new();
        for spec in self.model_specs() {
            anyhow::ensure!(
                seen.insert(spec.name.clone()),
                "duplicate model name {:?} in registry",
                spec.name
            );
            anyhow::ensure!(
                spec.backend != Backend::Remote || spec.remote.is_some(),
                "remote model {:?} needs an address (remote:tcp:HOST:PORT)",
                spec.name
            );
        }
        // Replica logical names and member entry names share the registry
        // namespace with plain models.
        for r in &self.replicas {
            anyhow::ensure!(
                seen.insert(r.name.clone()),
                "replica set name {:?} collides with a registry entry",
                r.name
            );
            for member in r.member_names() {
                anyhow::ensure!(
                    seen.insert(member.clone()),
                    "replica member name {member:?} collides with a registry entry"
                );
            }
        }
        Ok(())
    }

    /// Registry entries the replica sets add: one per member, local
    /// members on the member's backend with the base model's geometry,
    /// remote members proxied to their address.
    pub fn replica_model_specs(&self) -> Vec<ModelSpec> {
        let mut specs = Vec::new();
        for r in &self.replicas {
            for (name, m) in r.member_names().into_iter().zip(&r.members) {
                specs.push(ModelSpec {
                    name,
                    backend: m.backend,
                    model: self.model.clone(),
                    remote: m.remote.clone(),
                });
            }
        }
        specs
    }

    pub fn apply_file(&mut self, path: &Path) -> Result<()> {
        let text = std::fs::read_to_string(path)?;
        let v = Value::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        if let Some(m) = v.get("model") {
            self.model.apply_json(m);
        }
        if let Some(b) = v.get("backend").and_then(Value::as_str) {
            self.backend = Backend::parse(b)?;
        }
        if let Some(w) = v.get("workers").and_then(Value::as_usize) {
            self.workers = w;
        }
        if let Some(b) = v.get("max_batch").and_then(Value::as_usize) {
            self.max_batch = b;
        }
        if let Some(w) = v.get("max_wait_us").and_then(Value::as_usize) {
            self.max_wait_us = w as u64;
        }
        if let Some(t) = v.get("apply_threads").and_then(Value::as_usize) {
            self.apply_threads = t;
        }
        if let Some(d) = v.get("artifact_dir").and_then(Value::as_str) {
            self.artifact_dir = d.to_string();
        }
        if let Some(s) = v.get("seed").and_then(Value::as_f64) {
            self.seed = s as u64;
        }
        if let Some(l) = v.get("listen").and_then(Value::as_str) {
            self.listen = ListenAddr::parse(l).map_err(|e| anyhow::anyhow!(e))?;
        }
        if let Some(c) = v.get("max_connections").and_then(Value::as_usize) {
            self.max_connections = c;
        }
        if let Some(t) = v.get("idle_timeout_ms").and_then(Value::as_usize) {
            self.idle_timeout_ms = t as u64;
        }
        if let Some(q) = v.get("queue_limit").and_then(Value::as_usize) {
            self.queue_limit = q;
        }
        if let Some(p) = v.get("route_policy").and_then(Value::as_str) {
            self.route_policy = RoutePolicy::parse(p).map_err(|e| anyhow::anyhow!(e))?;
        }
        if let Some(c) = v.get("cache_entries").and_then(Value::as_usize) {
            self.cache_entries = c;
        }
        if let Some(h) = v.get("health_interval_ms").and_then(Value::as_usize) {
            self.health_interval_ms = h as u64;
        }
        if let Some(m) = v.get("io_mode").and_then(Value::as_str) {
            self.io_mode = IoMode::parse(m).map_err(|e| anyhow::anyhow!(e))?;
        }
        if let Some(p) = v.get("io_poll_ms").and_then(Value::as_usize) {
            self.io_poll_ms = (p as u64).max(1);
        }
        if let Some(w) = v.get("breaker_window").and_then(Value::as_usize) {
            self.breaker_window = w;
        }
        if let Some(r) = v.get("breaker_trip_ratio").and_then(Value::as_f64) {
            self.breaker_trip_ratio = r;
        }
        if let Some(c) = v.get("breaker_cooldown_ms").and_then(Value::as_usize) {
            self.breaker_cooldown_ms = c as u64;
        }
        if let Some(r) = v.get("retry_max").and_then(Value::as_usize) {
            self.retry_max = r;
        }
        if let Some(b) = v.get("retry_budget_ms").and_then(Value::as_usize) {
            self.retry_budget_ms = b as u64;
        }
        if let Some(t) = v.get("remote_call_timeout_ms").and_then(Value::as_usize) {
            self.remote_call_timeout_ms = (t as u64).max(1);
        }
        if let Some(t) = v.get("remote_probe_timeout_ms").and_then(Value::as_usize) {
            self.remote_probe_timeout_ms = (t as u64).max(1);
        }
        if let Some(t) = v.get("remote_connect_timeout_ms").and_then(Value::as_usize) {
            self.remote_connect_timeout_ms = (t as u64).max(1);
        }
        if let Some(s) = v.get("fault_inject").and_then(Value::as_str) {
            self.fault_inject = if s.trim().is_empty() { None } else { Some(s.to_string()) };
        }
        if let Some(r) = v.get("trace_sample_rate").and_then(Value::as_f64) {
            self.trace_sample_rate = r;
        }
        if let Some(m) = v.get("trace_slow_ms").and_then(Value::as_usize) {
            self.trace_slow_ms = m as u64;
        }
        if let Some(l) = v.get("log_level").and_then(Value::as_str) {
            self.log_level = l.to_string();
        }
        if let Some(f) = v.get("log_format").and_then(Value::as_str) {
            self.log_format = f.to_string();
        }
        if let Some(d) = v.get("log_dest").and_then(Value::as_str) {
            self.log_dest = d.to_string();
        }
        if let Some(m) = v.get("metrics_listen").and_then(Value::as_str) {
            self.metrics_listen =
                if m.trim().is_empty() { None } else { Some(m.to_string()) };
        }
        if let Some(b) = v.get("log_rotate_bytes").and_then(Value::as_usize) {
            self.log_rotate_bytes = b as u64;
        }
        if let Some(k) = v.get("log_rotate_keep").and_then(Value::as_usize) {
            self.log_rotate_keep = k.max(1);
        }
        if let Some(Value::Bool(p)) = v.get("profile") {
            self.profile = *p;
        }
        if let Some(b) = v.get("batch_max").and_then(Value::as_usize) {
            self.max_batch = b.max(1);
        }
        if let Some(w) = v.get("batch_window_us").and_then(Value::as_usize) {
            self.max_wait_us = w as u64;
        }
        if let Some(reps) = v.get("replicas").and_then(Value::as_array) {
            let default_backend = self.backend;
            self.replicas = reps
                .iter()
                .map(|entry| -> Result<ReplicaSpec> {
                    let name = entry
                        .get("name")
                        .and_then(Value::as_str)
                        .ok_or_else(|| anyhow::anyhow!("replicas[] entry missing \"name\""))?
                        .to_string();
                    // Either an explicit member-spec list ("members":
                    // ["native:2", "remote:tcp:h:7777"]) or the legacy
                    // homogeneous backend+count shape.
                    if let Some(list) = entry.get("members").and_then(Value::as_array) {
                        let mut members = Vec::new();
                        for m in list {
                            let s = m.as_str().ok_or_else(|| {
                                anyhow::anyhow!("replicas[].members entries must be strings")
                            })?;
                            members.extend(MemberSpec::parse_run(s)?);
                        }
                        return ReplicaSpec::new(&name, members);
                    }
                    let backend = match entry.get("backend").and_then(Value::as_str) {
                        Some(b) => Backend::parse(b)?,
                        None => default_backend,
                    };
                    let count = entry.get("count").and_then(Value::as_usize).unwrap_or(1);
                    ReplicaSpec::homogeneous(&name, backend, count)
                })
                .collect::<Result<Vec<_>>>()?;
        }
        self.apply_models_json(&v)?;
        Ok(())
    }

    /// Materialize the `models` array of a config document. Each entry is
    /// `{"name": ..., "backend": ..., "model": {...}}`; the per-model
    /// config starts from the *current* top-level model and applies the
    /// entry's overrides, so shared geometry need not be repeated.
    /// [`Self::resolve`] calls this again after CLI flags so extras
    /// inherit the finalized base geometry, keeping every family on the
    /// same modeled points.
    fn apply_models_json(&mut self, v: &Value) -> Result<()> {
        let Some(models) = v.get("models").and_then(Value::as_array) else {
            return Ok(());
        };
        self.extra_models.clear();
        for entry in models {
            let name = entry
                .get("name")
                .and_then(Value::as_str)
                .ok_or_else(|| anyhow::anyhow!("models[] entry missing \"name\""))?
                .to_string();
            let (backend, remote) = match entry.get("backend").and_then(Value::as_str) {
                Some(b) => parse_backend_value(b)
                    .with_context(|| format!("models[] entry {name:?}"))?,
                None => (self.backend, None),
            };
            // A separate "remote" key also carries the address
            // ({"backend": "remote", "remote": "tcp:h:7777"}).
            let remote = match entry.get("remote").and_then(Value::as_str) {
                Some(addr) => Some(validate_remote_addr(addr)?),
                None => remote,
            };
            let mut model = self.model.clone();
            if let Some(m) = entry.get("model") {
                model.apply_json(m);
            }
            self.extra_models.push(ModelSpec { name, backend, model, remote });
        }
        Ok(())
    }

    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("model", self.model.to_json()),
            ("backend", json::s(self.backend.name())),
            (
                "models",
                json::arr(self.extra_models.iter().map(ModelSpec::to_json).collect()),
            ),
            ("workers", json::num(self.workers as f64)),
            ("max_batch", json::num(self.max_batch as f64)),
            ("max_wait_us", json::num(self.max_wait_us as f64)),
            ("apply_threads", json::num(self.apply_threads as f64)),
            ("artifact_dir", json::s(&self.artifact_dir)),
            ("seed", json::num(self.seed as f64)),
            ("listen", json::s(&self.listen.to_string())),
            ("max_connections", json::num(self.max_connections as f64)),
            ("idle_timeout_ms", json::num(self.idle_timeout_ms as f64)),
            ("queue_limit", json::num(self.queue_limit as f64)),
            (
                "replicas",
                json::arr(self.replicas.iter().map(ReplicaSpec::to_json).collect()),
            ),
            ("route_policy", json::s(self.route_policy.name())),
            ("cache_entries", json::num(self.cache_entries as f64)),
            ("health_interval_ms", json::num(self.health_interval_ms as f64)),
            ("io_mode", json::s(self.io_mode.name())),
            ("io_poll_ms", json::num(self.io_poll_ms as f64)),
            ("breaker_window", json::num(self.breaker_window as f64)),
            ("breaker_trip_ratio", json::num(self.breaker_trip_ratio)),
            ("breaker_cooldown_ms", json::num(self.breaker_cooldown_ms as f64)),
            ("retry_max", json::num(self.retry_max as f64)),
            ("retry_budget_ms", json::num(self.retry_budget_ms as f64)),
            ("remote_call_timeout_ms", json::num(self.remote_call_timeout_ms as f64)),
            ("remote_probe_timeout_ms", json::num(self.remote_probe_timeout_ms as f64)),
            ("remote_connect_timeout_ms", json::num(self.remote_connect_timeout_ms as f64)),
            (
                "fault_inject",
                match &self.fault_inject {
                    Some(s) => json::s(s),
                    None => Value::Null,
                },
            ),
            ("trace_sample_rate", json::num(self.trace_sample_rate)),
            ("trace_slow_ms", json::num(self.trace_slow_ms as f64)),
            ("log_level", json::s(&self.log_level)),
            ("log_format", json::s(&self.log_format)),
            ("log_dest", json::s(&self.log_dest)),
            (
                "metrics_listen",
                match &self.metrics_listen {
                    Some(s) => json::s(s),
                    None => Value::Null,
                },
            ),
            ("log_rotate_bytes", json::num(self.log_rotate_bytes as f64)),
            ("log_rotate_keep", json::num(self.log_rotate_keep as f64)),
            ("profile", Value::Bool(self.profile)),
        ])
    }

    /// The router's breaker tuning derived from these knobs.
    pub fn breaker_config(&self) -> crate::net::BreakerConfig {
        crate::net::BreakerConfig {
            window: self.breaker_window,
            trip_ratio: self.breaker_trip_ratio,
            cooldown: std::time::Duration::from_millis(self.breaker_cooldown_ms),
            // Bounded Half-Open trials; fixed — enough to tolerate one
            // unlucky trial without flooding a recovering member.
            trials: 2,
        }
    }

    /// Remote-client timeouts derived from these knobs.
    pub fn remote_timeouts(&self) -> crate::cluster::RemoteTimeouts {
        crate::cluster::RemoteTimeouts {
            call: std::time::Duration::from_millis(self.remote_call_timeout_ms),
            probe: std::time::Duration::from_millis(self.remote_probe_timeout_ms),
            connect: std::time::Duration::from_millis(self.remote_connect_timeout_ms),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(str::to_string).collect()
    }

    #[test]
    fn defaults_are_paper_config() {
        let cfg = ServerConfig::default();
        let p = cfg.model.refinement_params().unwrap();
        assert_eq!((p.n_csz, p.n_fsz, p.n_lvl), (5, 4, 5));
        assert_eq!(p.final_size(), 200);
        assert_eq!(cfg.model.kernel().unwrap().name(), "matern32");
    }

    #[test]
    fn paper_log_chart_spans_two_orders_of_magnitude() {
        let cfg = ModelConfig::default();
        let chart = cfg.chart().unwrap();
        let params = cfg.refinement_params().unwrap();
        let geo = crate::icr::Geometry::build(params);
        let pts: Vec<f64> = geo.final_positions().iter().map(|&u| chart.to_domain(u)).collect();
        let gaps: Vec<f64> = pts.windows(2).map(|w| w[1] - w[0]).collect();
        let dmin = gaps.iter().cloned().fold(f64::INFINITY, f64::min);
        let dmax = gaps.iter().cloned().fold(0.0_f64, f64::max);
        assert!((dmin - 0.02).abs() < 1e-9, "dmin {dmin}");
        assert!((dmax - 1.0).abs() < 1e-8, "dmax {dmax}");
    }

    #[test]
    fn cli_overrides_defaults() {
        let args = Args::parse(
            &argv("serve --backend pjrt --workers 4 --csz 3 --fsz 2 --n 128 --seed 7 --apply-threads 3"),
            &[],
        )
        .unwrap();
        let cfg = ServerConfig::resolve(&args).unwrap();
        assert_eq!(cfg.backend, Backend::Pjrt);
        assert_eq!(cfg.workers, 4);
        assert_eq!(cfg.model.n_csz, 3);
        assert_eq!(cfg.model.target_n, 128);
        assert_eq!(cfg.seed, 7);
        assert_eq!(cfg.apply_threads, 3);
    }

    #[test]
    fn apply_threads_defaults_and_json_roundtrip() {
        // The default honors ICR_APPLY_THREADS (CI forces 4 through the
        // pool); unset it is 1.
        let want = crate::parallel::default_apply_threads();
        let cfg = ServerConfig::default();
        assert_eq!(cfg.apply_threads, want);
        let v = Value::parse(&cfg.to_json().to_json_pretty()).unwrap();
        assert_eq!(v.get("apply_threads").unwrap().as_usize(), Some(want));
        // `0` (auto) is representable from file config.
        let dir = std::env::temp_dir();
        let path = dir.join(format!("icr_threads_{}.json", std::process::id()));
        std::fs::write(&path, r#"{"apply_threads": 0, "max_batch": 16}"#).unwrap();
        let args =
            Args::parse(&argv(&format!("serve --config {}", path.display())), &[]).unwrap();
        let cfg = ServerConfig::resolve(&args).unwrap();
        assert_eq!(cfg.apply_threads, 0);
        assert_eq!(cfg.max_batch, 16);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn file_then_cli_layering() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("icr_cfg_{}.json", std::process::id()));
        std::fs::write(
            &path,
            r#"{"backend": "pjrt", "workers": 8, "model": {"n_csz": 3, "n_fsz": 2, "target_n": 300}}"#,
        )
        .unwrap();
        let args = Args::parse(
            &argv(&format!("serve --config {} --workers 2", path.display())),
            &[],
        )
        .unwrap();
        let cfg = ServerConfig::resolve(&args).unwrap();
        assert_eq!(cfg.backend, Backend::Pjrt); // from file
        assert_eq!(cfg.workers, 2); // CLI wins
        assert_eq!(cfg.model.n_csz, 3); // from file
        assert_eq!(cfg.model.target_n, 300);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn config_roundtrips_through_json() {
        let cfg = ServerConfig::default();
        let dumped = cfg.to_json().to_json_pretty();
        let v = Value::parse(&dumped).unwrap();
        assert_eq!(v.get_path("model.n_csz").unwrap().as_usize(), Some(5));
        assert_eq!(v.get("backend").unwrap().as_str(), Some("native"));
    }

    #[test]
    fn bad_backend_rejected() {
        assert!(Backend::parse("tpu-cluster").is_err());
    }

    #[test]
    fn all_backends_roundtrip_names() {
        for b in
            [Backend::Native, Backend::Pjrt, Backend::Kissgp, Backend::Exact, Backend::Remote]
        {
            assert_eq!(Backend::parse(b.name()).unwrap(), b);
            assert!(MODEL_FAMILIES.contains(&b.name()));
        }
    }

    #[test]
    fn models_flag_builds_named_registry() {
        let args = Args::parse(&argv("serve --models kiss=kissgp,ref=exact --n 48"), &[]).unwrap();
        let cfg = ServerConfig::resolve(&args).unwrap();
        let specs = cfg.model_specs();
        assert_eq!(specs.len(), 3);
        assert_eq!(specs[0].name, DEFAULT_MODEL_NAME);
        assert_eq!(specs[1].name, "kiss");
        assert_eq!(specs[1].backend, Backend::Kissgp);
        assert_eq!(specs[2].name, "ref");
        assert_eq!(specs[2].backend, Backend::Exact);
        // Extras inherit the (CLI-overridden) default geometry.
        assert_eq!(specs[1].model.target_n, 48);
    }

    #[test]
    fn listen_and_serving_knobs_resolve_from_cli() {
        let args = Args::parse(
            &argv(
                "serve --listen tcp:127.0.0.1:7070 --max-connections 8 \
                 --idle-timeout-ms 1500 --queue-limit 32 \
                 --replicas gp=native:3,ref=exact --route-policy round_robin \
                 --cache-entries 64 --health-interval-ms 500",
            ),
            &[],
        )
        .unwrap();
        let cfg = ServerConfig::resolve(&args).unwrap();
        assert_eq!(cfg.listen, ListenAddr::Tcp("127.0.0.1:7070".into()));
        assert_eq!(cfg.max_connections, 8);
        assert_eq!(cfg.idle_timeout_ms, 1500);
        assert_eq!(cfg.queue_limit, 32);
        assert_eq!(cfg.route_policy, RoutePolicy::RoundRobin);
        assert_eq!(cfg.cache_entries, 64);
        assert_eq!(cfg.health_interval_ms, 500);
        assert_eq!(cfg.replicas.len(), 2);
        assert_eq!(cfg.replicas[0].name, "gp");
        assert_eq!(cfg.replicas[0].count(), 3);
        assert_eq!(cfg.replicas[0].member_names(), vec!["gp@0", "gp@1", "gp@2"]);
        assert_eq!(cfg.replicas[1].members[0].backend, Backend::Exact);
        assert_eq!(cfg.replicas[1].count(), 1);
        let member_specs = cfg.replica_model_specs();
        assert_eq!(member_specs.len(), 4);
        assert_eq!(member_specs[0].name, "gp@0");
        assert_eq!(member_specs[3].backend, Backend::Exact);
    }

    #[test]
    fn io_and_batching_knobs_resolve_from_cli() {
        // Defaults: event loop, 25 ms blocking poll.
        let cfg = ServerConfig::default();
        assert_eq!(cfg.io_mode, IoMode::default());
        assert_eq!(cfg.io_poll_ms, 25);
        let args = Args::parse(
            &argv(
                "serve --io-mode threads --io-poll-ms 5 --batch-max 12 --batch-window-us 400",
            ),
            &[],
        )
        .unwrap();
        let cfg = ServerConfig::resolve(&args).unwrap();
        assert_eq!(cfg.io_mode, IoMode::Threads);
        assert_eq!(cfg.io_poll_ms, 5);
        assert_eq!(cfg.max_batch, 12);
        assert_eq!(cfg.max_wait_us, 400);
        // The preferred spellings win over the legacy aliases.
        let args = Args::parse(
            &argv("serve --max-batch 3 --batch-max 9 --max-wait-us 10 --batch-window-us 20"),
            &[],
        )
        .unwrap();
        let cfg = ServerConfig::resolve(&args).unwrap();
        assert_eq!(cfg.max_batch, 9);
        assert_eq!(cfg.max_wait_us, 20);
        // io_poll_ms is clamped to at least 1 ms; bad modes are rejected.
        let args = Args::parse(&argv("serve --io-poll-ms 0"), &[]).unwrap();
        assert_eq!(ServerConfig::resolve(&args).unwrap().io_poll_ms, 1);
        let args = Args::parse(&argv("serve --io-mode fibers"), &[]).unwrap();
        assert!(ServerConfig::resolve(&args).is_err());
    }

    #[test]
    fn io_and_batching_knobs_from_config_file() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("icr_io_{}.json", std::process::id()));
        std::fs::write(
            &path,
            r#"{"io_mode": "threads", "io_poll_ms": 10,
                "batch_max": 6, "batch_window_us": 150}"#,
        )
        .unwrap();
        let args =
            Args::parse(&argv(&format!("serve --config {}", path.display())), &[]).unwrap();
        let cfg = ServerConfig::resolve(&args).unwrap();
        assert_eq!(cfg.io_mode, IoMode::Threads);
        assert_eq!(cfg.io_poll_ms, 10);
        assert_eq!(cfg.max_batch, 6);
        assert_eq!(cfg.max_wait_us, 150);
        // Both knobs ride through the config dump.
        let v = Value::parse(&cfg.to_json().to_json_pretty()).unwrap();
        assert_eq!(v.get("io_mode").and_then(Value::as_str), Some("threads"));
        assert_eq!(v.get("io_poll_ms").and_then(Value::as_usize), Some(10));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn resilience_knobs_resolve_from_cli() {
        // Defaults leave historical behavior untouched.
        let cfg = ServerConfig::default();
        assert_eq!(cfg.breaker_window, 16);
        assert_eq!(cfg.breaker_trip_ratio, 0.5);
        assert_eq!(cfg.breaker_cooldown_ms, 1000);
        assert_eq!(cfg.retry_max, 2);
        assert_eq!(cfg.retry_budget_ms, 10_000);
        assert_eq!(cfg.remote_call_timeout_ms, 120_000);
        assert_eq!(cfg.remote_probe_timeout_ms, 2_000);
        assert_eq!(cfg.remote_connect_timeout_ms, 5_000);
        assert_eq!(cfg.fault_inject, None);

        let args = Args::parse(
            &argv(
                "serve --breaker-window 8 --breaker-trip-ratio 0.25 --breaker-cooldown-ms 200 \
                 --retry-max 4 --retry-budget-ms 2500 --remote-call-timeout-ms 9000 \
                 --remote-probe-timeout-ms 700 --remote-connect-timeout-ms 1500 \
                 --fault-inject remote:error=0.1,delay_ms=5",
            ),
            &[],
        )
        .unwrap();
        let cfg = ServerConfig::resolve(&args).unwrap();
        assert_eq!(cfg.breaker_window, 8);
        assert_eq!(cfg.breaker_trip_ratio, 0.25);
        assert_eq!(cfg.breaker_cooldown_ms, 200);
        assert_eq!(cfg.retry_max, 4);
        assert_eq!(cfg.retry_budget_ms, 2500);
        assert_eq!(cfg.remote_call_timeout_ms, 9000);
        assert_eq!(cfg.remote_probe_timeout_ms, 700);
        assert_eq!(cfg.remote_connect_timeout_ms, 1500);
        assert_eq!(cfg.fault_inject.as_deref(), Some("remote:error=0.1,delay_ms=5"));
        // Derived tunings mirror the knobs.
        let b = cfg.breaker_config();
        assert_eq!(b.window, 8);
        assert_eq!(b.trip_ratio, 0.25);
        assert_eq!(b.cooldown, std::time::Duration::from_millis(200));
        let t = cfg.remote_timeouts();
        assert_eq!(t.call, std::time::Duration::from_millis(9000));
        assert_eq!(t.probe, std::time::Duration::from_millis(700));
        assert_eq!(t.connect, std::time::Duration::from_millis(1500));

        // Out-of-range ratios and malformed chaos specs are startup errors.
        let args = Args::parse(&argv("serve --breaker-trip-ratio 0"), &[]).unwrap();
        assert!(ServerConfig::resolve(&args).is_err());
        let args = Args::parse(&argv("serve --breaker-trip-ratio 1.5"), &[]).unwrap();
        assert!(ServerConfig::resolve(&args).is_err());
        let args = Args::parse(&argv("serve --fault-inject remote:error=2"), &[]).unwrap();
        assert!(ServerConfig::resolve(&args).is_err());
        let args = Args::parse(&argv("serve --fault-inject bogus"), &[]).unwrap();
        assert!(ServerConfig::resolve(&args).is_err());
    }

    #[test]
    fn resilience_knobs_from_config_file_and_dump() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("icr_resilience_{}.json", std::process::id()));
        std::fs::write(
            &path,
            r#"{"breaker_window": 6, "breaker_trip_ratio": 0.75,
                "breaker_cooldown_ms": 300, "retry_max": 1, "retry_budget_ms": 800,
                "remote_call_timeout_ms": 4000, "remote_probe_timeout_ms": 900,
                "remote_connect_timeout_ms": 1100,
                "fault_inject": "local:error=0.5"}"#,
        )
        .unwrap();
        let args =
            Args::parse(&argv(&format!("serve --config {}", path.display())), &[]).unwrap();
        let cfg = ServerConfig::resolve(&args).unwrap();
        assert_eq!(cfg.breaker_window, 6);
        assert_eq!(cfg.breaker_trip_ratio, 0.75);
        assert_eq!(cfg.breaker_cooldown_ms, 300);
        assert_eq!(cfg.retry_max, 1);
        assert_eq!(cfg.retry_budget_ms, 800);
        assert_eq!(cfg.remote_call_timeout_ms, 4000);
        assert_eq!(cfg.remote_probe_timeout_ms, 900);
        assert_eq!(cfg.remote_connect_timeout_ms, 1100);
        assert_eq!(cfg.fault_inject.as_deref(), Some("local:error=0.5"));
        // Every knob rides through the config dump and back.
        let v = Value::parse(&cfg.to_json().to_json_pretty()).unwrap();
        assert_eq!(v.get("breaker_window").and_then(Value::as_usize), Some(6));
        assert_eq!(v.get("breaker_trip_ratio").and_then(Value::as_f64), Some(0.75));
        assert_eq!(v.get("retry_max").and_then(Value::as_usize), Some(1));
        assert_eq!(v.get("retry_budget_ms").and_then(Value::as_usize), Some(800));
        assert_eq!(v.get("remote_call_timeout_ms").and_then(Value::as_usize), Some(4000));
        assert_eq!(v.get("fault_inject").and_then(Value::as_str), Some("local:error=0.5"));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn obs_knobs_resolve_from_cli() {
        // Defaults: tracing off, info-level JSON logging to stderr,
        // no scrape endpoint — historical behavior untouched.
        let cfg = ServerConfig::default();
        assert_eq!(cfg.trace_sample_rate, 0.0);
        assert_eq!(cfg.trace_slow_ms, 0);
        assert_eq!(cfg.log_level, "info");
        assert_eq!(cfg.log_format, "json");
        assert_eq!(cfg.log_dest, "stderr");
        assert_eq!(cfg.metrics_listen, None);

        let args = Args::parse(
            &argv(
                "serve --trace-sample-rate 0.25 --trace-slow-ms 50 --log-level debug \
                 --log-format text --log-dest file:/tmp/icr-obs.log \
                 --metrics-listen tcp:127.0.0.1:9100",
            ),
            &[],
        )
        .unwrap();
        let cfg = ServerConfig::resolve(&args).unwrap();
        assert_eq!(cfg.trace_sample_rate, 0.25);
        assert_eq!(cfg.trace_slow_ms, 50);
        assert_eq!(cfg.log_level, "debug");
        assert_eq!(cfg.log_format, "text");
        assert_eq!(cfg.log_dest, "file:/tmp/icr-obs.log");
        assert_eq!(cfg.metrics_listen.as_deref(), Some("tcp:127.0.0.1:9100"));

        // Invalid knob values are startup errors, not silent defaults.
        for bad in [
            "serve --trace-sample-rate 1.5",
            "serve --trace-sample-rate -0.1",
            "serve --log-level loud",
            "serve --log-format xml",
            "serve --log-dest syslog",
            "serve --metrics-listen stdio",
            "serve --metrics-listen unix:/tmp/m.sock",
            "serve --metrics-listen 127.0.0.1:9100",
        ] {
            let args = Args::parse(&argv(bad), &[]).unwrap();
            assert!(ServerConfig::resolve(&args).is_err(), "{bad} should fail");
        }
    }

    #[test]
    fn obs_knobs_from_config_file_and_dump() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("icr_obs_{}.json", std::process::id()));
        std::fs::write(
            &path,
            r#"{"trace_sample_rate": 0.5, "trace_slow_ms": 20,
                "log_level": "warn", "log_format": "text",
                "log_dest": "stderr", "metrics_listen": "tcp:0.0.0.0:9100"}"#,
        )
        .unwrap();
        let args =
            Args::parse(&argv(&format!("serve --config {}", path.display())), &[]).unwrap();
        let cfg = ServerConfig::resolve(&args).unwrap();
        assert_eq!(cfg.trace_sample_rate, 0.5);
        assert_eq!(cfg.trace_slow_ms, 20);
        assert_eq!(cfg.log_level, "warn");
        assert_eq!(cfg.log_format, "text");
        assert_eq!(cfg.metrics_listen.as_deref(), Some("tcp:0.0.0.0:9100"));
        // Every knob rides through the config dump and back.
        let v = Value::parse(&cfg.to_json().to_json_pretty()).unwrap();
        assert_eq!(v.get("trace_sample_rate").and_then(Value::as_f64), Some(0.5));
        assert_eq!(v.get("trace_slow_ms").and_then(Value::as_usize), Some(20));
        assert_eq!(v.get("log_level").and_then(Value::as_str), Some("warn"));
        assert_eq!(v.get("log_format").and_then(Value::as_str), Some("text"));
        assert_eq!(v.get("log_dest").and_then(Value::as_str), Some("stderr"));
        assert_eq!(
            v.get("metrics_listen").and_then(Value::as_str),
            Some("tcp:0.0.0.0:9100")
        );
        // Defaults dump metrics_listen as null.
        let v = Value::parse(&ServerConfig::default().to_json().to_json()).unwrap();
        assert_eq!(v.get("metrics_listen"), Some(&Value::Null));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn profiling_and_rotation_knobs_resolve_and_roundtrip() {
        // Defaults: profiler off, rotation off, keep 3.
        let cfg = ServerConfig::default();
        assert!(!cfg.profile);
        assert_eq!(cfg.log_rotate_bytes, 0);
        assert_eq!(cfg.log_rotate_keep, 3);

        let args = Args::parse(
            &argv("serve --profile --log-rotate-bytes 4096 --log-rotate-keep 5"),
            &["profile"],
        )
        .unwrap();
        let cfg = ServerConfig::resolve(&args).unwrap();
        assert!(cfg.profile);
        assert_eq!(cfg.log_rotate_bytes, 4096);
        assert_eq!(cfg.log_rotate_keep, 5);
        let v = Value::parse(&cfg.to_json().to_json_pretty()).unwrap();
        assert_eq!(v.get("profile"), Some(&Value::Bool(true)));
        assert_eq!(v.get("log_rotate_bytes").and_then(Value::as_usize), Some(4096));
        assert_eq!(v.get("log_rotate_keep").and_then(Value::as_usize), Some(5));

        // File config carries the same keys; keep must stay >= 1.
        let dir = std::env::temp_dir();
        let path = dir.join(format!("icr_prof_{}.json", std::process::id()));
        std::fs::write(
            &path,
            r#"{"profile": true, "log_rotate_bytes": 1024, "log_rotate_keep": 2}"#,
        )
        .unwrap();
        let args =
            Args::parse(&argv(&format!("serve --config {}", path.display())), &[]).unwrap();
        let cfg = ServerConfig::resolve(&args).unwrap();
        assert!(cfg.profile);
        assert_eq!(cfg.log_rotate_bytes, 1024);
        assert_eq!(cfg.log_rotate_keep, 2);
        std::fs::remove_file(&path).ok();

        let args = Args::parse(&argv("serve --log-rotate-keep 0"), &[]).unwrap();
        assert!(ServerConfig::resolve(&args).is_err(), "keep 0 must be rejected");
    }

    #[test]
    fn mixed_local_remote_replica_sets_parse() {
        // Bare pieces after a set extend it: one 4-member mixed set.
        let sets =
            ReplicaSpec::parse_list("gp=native:2,remote:tcp:h1:7777,remote:tcp:h2:7777").unwrap();
        assert_eq!(sets.len(), 1);
        let gp = &sets[0];
        assert_eq!(gp.count(), 4);
        assert_eq!(gp.member_names(), vec!["gp@0", "gp@1", "gp@2", "gp@3"]);
        assert_eq!(gp.members[0], MemberSpec::local(Backend::Native));
        assert_eq!(gp.members[2].backend, Backend::Remote);
        assert_eq!(gp.members[2].remote.as_deref(), Some("tcp:h1:7777"));
        assert_eq!(gp.members[3].remote.as_deref(), Some("tcp:h2:7777"));
        // Spec strings round-trip.
        assert_eq!(gp.members[3].spec_string(), "remote:tcp:h2:7777");
        assert_eq!(MemberSpec::parse_run("remote:tcp:h2:7777").unwrap(), vec![gp.members[3].clone()]);
        // Member specs materialize with the remote address attached.
        let cfg = ServerConfig { replicas: sets, ..ServerConfig::default() };
        let specs = cfg.replica_model_specs();
        assert_eq!(specs[1].backend, Backend::Native);
        assert_eq!(specs[1].remote, None);
        assert_eq!(specs[3].backend, Backend::Remote);
        assert_eq!(specs[3].remote.as_deref(), Some("tcp:h2:7777"));

        // A leading bare piece has no set to extend; malformed remote
        // addresses and addressless remote members are rejected.
        assert!(ReplicaSpec::parse_list("remote:tcp:h1:7777").is_err());
        assert!(ReplicaSpec::parse_list("gp=remote:unix:/x").is_err());
        assert!(ReplicaSpec::parse_list("gp=remote:tcp:h1").is_err());
        assert!(ReplicaSpec::parse_list("gp=remote").is_err());
    }

    #[test]
    fn models_flag_accepts_remote_entries() {
        let args =
            Args::parse(&argv("serve --models gp=remote:tcp:127.0.0.1:7777,ref=exact"), &[])
                .unwrap();
        let cfg = ServerConfig::resolve(&args).unwrap();
        assert_eq!(cfg.extra_models[0].backend, Backend::Remote);
        assert_eq!(cfg.extra_models[0].remote.as_deref(), Some("tcp:127.0.0.1:7777"));
        assert_eq!(cfg.extra_models[1].backend, Backend::Exact);
        assert_eq!(cfg.extra_models[1].remote, None);
        // An addressless remote entry fails validation with a clear error.
        let args = Args::parse(&argv("serve --models gp=remote"), &[]).unwrap();
        assert!(ServerConfig::resolve(&args).is_err());
        // Config dump carries the address.
        let v = Value::parse(&cfg.to_json().to_json_pretty()).unwrap();
        assert_eq!(
            v.get_path("models").and_then(Value::as_array).unwrap()[0]
                .get("remote")
                .and_then(Value::as_str),
            Some("tcp:127.0.0.1:7777")
        );
    }

    #[test]
    fn serving_knobs_default_to_stdio_and_unbounded() {
        let cfg = ServerConfig::default();
        assert_eq!(cfg.listen, ListenAddr::Stdio);
        assert_eq!(cfg.queue_limit, 0);
        assert!(cfg.replicas.is_empty());
        assert_eq!(cfg.route_policy, RoutePolicy::SeedAffinity);
        // The response cache is off by default; the health monitor is on
        // (it only runs when replica sets exist).
        assert_eq!(cfg.cache_entries, 0);
        assert_eq!(cfg.health_interval_ms, 2000);
    }

    #[test]
    fn serving_knobs_from_config_file() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("icr_net_{}.json", std::process::id()));
        std::fs::write(
            &path,
            r#"{"listen": "unix:/tmp/icr-test.sock", "max_connections": 4,
                "idle_timeout_ms": 250, "queue_limit": 16,
                "route_policy": "least_outstanding",
                "cache_entries": 32, "health_interval_ms": 750,
                "replicas": [{"name": "gp", "count": 2},
                             {"name": "mix", "members": ["exact", "remote:tcp:h1:7070"]}]}"#,
        )
        .unwrap();
        let args =
            Args::parse(&argv(&format!("serve --config {}", path.display())), &[]).unwrap();
        let cfg = ServerConfig::resolve(&args).unwrap();
        assert_eq!(cfg.listen, ListenAddr::Unix("/tmp/icr-test.sock".into()));
        assert_eq!(cfg.max_connections, 4);
        assert_eq!(cfg.idle_timeout_ms, 250);
        assert_eq!(cfg.queue_limit, 16);
        assert_eq!(cfg.route_policy, RoutePolicy::LeastOutstanding);
        assert_eq!(cfg.cache_entries, 32);
        assert_eq!(cfg.health_interval_ms, 750);
        assert_eq!(
            cfg.replicas[0],
            ReplicaSpec::homogeneous("gp", Backend::Native, 2).unwrap()
        );
        assert_eq!(cfg.replicas[1].members[0], MemberSpec::local(Backend::Exact));
        assert_eq!(cfg.replicas[1].members[1].remote.as_deref(), Some("tcp:h1:7070"));
        // And the new knobs ride through the config dump.
        let v = Value::parse(&cfg.to_json().to_json_pretty()).unwrap();
        assert_eq!(v.get("listen").and_then(Value::as_str), Some("unix:/tmp/icr-test.sock"));
        assert_eq!(v.get("route_policy").and_then(Value::as_str), Some("least_outstanding"));
        assert_eq!(v.get("cache_entries").and_then(Value::as_usize), Some(32));
        assert_eq!(v.get("health_interval_ms").and_then(Value::as_usize), Some(750));
        let reps = v.get_path("replicas").and_then(Value::as_array).unwrap();
        assert_eq!(reps.len(), 2);
        let mix_members = reps[1].get("members").and_then(Value::as_array).unwrap();
        assert_eq!(mix_members[1].as_str(), Some("remote:tcp:h1:7070"));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn replica_names_may_not_collide() {
        // Logical set name colliding with a model name.
        let args =
            Args::parse(&argv("serve --models gp=exact --replicas gp=native:2"), &[]).unwrap();
        assert!(ServerConfig::resolve(&args).is_err());
        // Member name colliding with an explicit model name.
        let args =
            Args::parse(&argv("serve --models gp@0=exact --replicas gp=native:2"), &[]).unwrap();
        assert!(ServerConfig::resolve(&args).is_err());
        // '@' reserved in logical names; zero count rejected — on the
        // CLI path and the shared constructor the config file uses.
        assert!(ReplicaSpec::parse_list("a@b=native:2").is_err());
        assert!(ReplicaSpec::parse_list("gp=native:0").is_err());
        assert!(ReplicaSpec::parse_list("gp").is_err());
        assert_eq!(ReplicaSpec::parse_list("gp=kissgp").unwrap()[0].count(), 1);
        assert!(ReplicaSpec::homogeneous("a@b", Backend::Native, 2).is_err());
        assert!(ReplicaSpec::homogeneous("  ", Backend::Native, 2).is_err());
        // The config-file path funnels through the same validation.
        let dir = std::env::temp_dir();
        let path = dir.join(format!("icr_badrep_{}.json", std::process::id()));
        std::fs::write(&path, r#"{"replicas": [{"name": "a@b", "count": 2}]}"#).unwrap();
        let args =
            Args::parse(&argv(&format!("serve --config {}", path.display())), &[]).unwrap();
        assert!(ServerConfig::resolve(&args).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn duplicate_model_names_rejected() {
        let args = Args::parse(&argv("serve --models a=native,a=exact"), &[]).unwrap();
        assert!(ServerConfig::resolve(&args).is_err());
        let args = Args::parse(&argv("serve --models default=exact"), &[]).unwrap();
        assert!(ServerConfig::resolve(&args).is_err());
    }

    #[test]
    fn models_from_config_file_with_overrides() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("icr_models_{}.json", std::process::id()));
        std::fs::write(
            &path,
            r#"{"model": {"n_csz": 3, "n_fsz": 2, "target_n": 40},
                "models": [{"name": "kiss", "backend": "kissgp"},
                           {"name": "big", "model": {"target_n": 96}}]}"#,
        )
        .unwrap();
        let args = Args::parse(&argv(&format!("serve --config {}", path.display())), &[]).unwrap();
        let cfg = ServerConfig::resolve(&args).unwrap();
        assert_eq!(cfg.extra_models.len(), 2);
        assert_eq!(cfg.extra_models[0].backend, Backend::Kissgp);
        assert_eq!(cfg.extra_models[0].model.target_n, 40); // inherited
        assert_eq!(cfg.extra_models[1].backend, Backend::Native); // inherited
        assert_eq!(cfg.extra_models[1].model.target_n, 96); // overridden

        // CLI flags finalize the base model BEFORE extras materialize, so
        // file-declared extras share the final geometry.
        let args =
            Args::parse(&argv(&format!("serve --config {} --n 64", path.display())), &[]).unwrap();
        let cfg = ServerConfig::resolve(&args).unwrap();
        assert_eq!(cfg.model.target_n, 64);
        assert_eq!(cfg.extra_models[0].model.target_n, 64); // follows CLI
        assert_eq!(cfg.extra_models[1].model.target_n, 96); // own override wins
        std::fs::remove_file(&path).ok();
    }
}
