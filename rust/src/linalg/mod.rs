//! Dense linear algebra substrate.
//!
//! The paper's evaluation (Fig. 3, the §5.1 KL parameter-selection table and
//! the rank probe) needs exact dense operations on moderate matrices
//! (N ≈ 200): kernel-matrix assembly, Cholesky factorization, triangular
//! solves, log-determinants and a symmetric eigensolver. No external linear
//! algebra crate is available in this environment, so the substrate is
//! implemented from scratch here. Everything is `f64` (the paper benchmarks
//! in double precision).
//!
//! The matrix type is row-major and deliberately simple; hot paths that
//! matter for the paper's claims (the O(N) ICR apply) do not go through
//! this module — they use flat slices in [`crate::icr`].

mod matrix;
mod cholesky;
mod eigen;
mod solve;

pub use matrix::Matrix;
pub use cholesky::Cholesky;
pub use eigen::{jacobi_eigenvalues, jacobi_eigh, symmetric_rank};
pub use solve::{solve_lower, solve_lower_transpose, solve_upper};

/// Machine-epsilon-scaled tolerance used by rank probes and PSD checks.
pub const EPS_TOL: f64 = 1e-10;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn public_api_reexports_compile() {
        let m = Matrix::eye(3);
        let c = Cholesky::new(&m).unwrap();
        assert!((c.logdet() - 0.0).abs() < 1e-14);
    }
}
