//! Symmetric eigensolver (cyclic Jacobi).
//!
//! Used by the §5.2 rank probe — the paper contrasts ICR's guaranteed
//! full-rank `K_ICR = √K·√Kᵀ` with KISS-GP's generally singular
//! `W·K_UU·Wᵀ`. Jacobi rotations are slow (O(n³) per sweep) but
//! unconditionally robust and accurate for the N ≈ 200 matrices of the
//! evaluation, which is exactly what a rank probe needs.

use super::matrix::Matrix;

/// Eigenvalues of a symmetric matrix, ascending.
pub fn jacobi_eigenvalues(a: &Matrix) -> Vec<f64> {
    jacobi_eigh(a, false).0
}

/// Eigendecomposition of a symmetric matrix via cyclic Jacobi sweeps.
///
/// Returns `(eigenvalues_ascending, Some(V))` with `A = V·diag(λ)·Vᵀ` when
/// `want_vectors`, else `(eigenvalues_ascending, None)`. Only the lower
/// triangle of `a` is trusted; the matrix is symmetrized internally.
pub fn jacobi_eigh(a: &Matrix, want_vectors: bool) -> (Vec<f64>, Option<Matrix>) {
    assert!(a.is_square(), "eigh of non-square matrix");
    let n = a.rows();
    let mut m = a.clone();
    m.symmetrize();
    let mut v = if want_vectors { Some(Matrix::eye(n)) } else { None };

    let max_sweeps = 64;
    for _sweep in 0..max_sweeps {
        // Off-diagonal Frobenius norm as convergence measure.
        let mut off = 0.0;
        for r in 0..n {
            for c in (r + 1)..n {
                off += m[(r, c)] * m[(r, c)];
            }
        }
        if off.sqrt() < 1e-14 * (1.0 + m.frobenius_norm()) {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[(p, q)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                // Stable rotation computation (Golub & Van Loan §8.4).
                let tau = (aqq - app) / (2.0 * apq);
                let t = if tau >= 0.0 {
                    1.0 / (tau + (1.0 + tau * tau).sqrt())
                } else {
                    -1.0 / (-tau + (1.0 + tau * tau).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;
                // Apply rotation J(p,q,θ)ᵀ · M · J(p,q,θ).
                for k in 0..n {
                    let mkp = m[(k, p)];
                    let mkq = m[(k, q)];
                    m[(k, p)] = c * mkp - s * mkq;
                    m[(k, q)] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[(p, k)];
                    let mqk = m[(q, k)];
                    m[(p, k)] = c * mpk - s * mqk;
                    m[(q, k)] = s * mpk + c * mqk;
                }
                if let Some(vm) = v.as_mut() {
                    for k in 0..n {
                        let vkp = vm[(k, p)];
                        let vkq = vm[(k, q)];
                        vm[(k, p)] = c * vkp - s * vkq;
                        vm[(k, q)] = s * vkp + c * vkq;
                    }
                }
            }
        }
    }

    let mut idx: Vec<usize> = (0..n).collect();
    let diag: Vec<f64> = (0..n).map(|i| m[(i, i)]).collect();
    idx.sort_by(|&i, &j| diag[i].partial_cmp(&diag[j]).unwrap());
    let evals: Vec<f64> = idx.iter().map(|&i| diag[i]).collect();
    let evecs = v.map(|vm| {
        let mut sorted = Matrix::zeros(n, n);
        for (newc, &oldc) in idx.iter().enumerate() {
            for r in 0..n {
                sorted[(r, newc)] = vm[(r, oldc)];
            }
        }
        sorted
    });
    (evals, evecs)
}

/// Numerical rank of a symmetric PSD matrix: eigenvalues above
/// `rel_tol · λ_max` count. This is the Fig. 3 / §5.2 rank probe.
pub fn symmetric_rank(a: &Matrix, rel_tol: f64) -> usize {
    let ev = jacobi_eigenvalues(a);
    let lmax = ev.iter().fold(0.0_f64, |m, &v| m.max(v.abs()));
    if lmax == 0.0 {
        return 0;
    }
    ev.iter().filter(|&&v| v > rel_tol * lmax).count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Cholesky;

    #[test]
    fn diagonal_matrix_eigenvalues() {
        let a = Matrix::from_rows(&[&[3.0, 0.0, 0.0], &[0.0, 1.0, 0.0], &[0.0, 0.0, 2.0]]);
        let ev = jacobi_eigenvalues(&a);
        assert!((ev[0] - 1.0).abs() < 1e-12);
        assert!((ev[1] - 2.0).abs() < 1e-12);
        assert!((ev[2] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] has eigenvalues 1 and 3.
        let a = Matrix::from_rows(&[&[2.0, 1.0], &[1.0, 2.0]]);
        let ev = jacobi_eigenvalues(&a);
        assert!((ev[0] - 1.0).abs() < 1e-12);
        assert!((ev[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn reconstruction_from_eigh() {
        let b = Matrix::from_fn(5, 5, |r, c| ((r * 5 + c) as f64 * 0.17).sin());
        let mut a = b.matmul_nt(&b);
        a.symmetrize();
        let (ev, v) = jacobi_eigh(&a, true);
        let v = v.unwrap();
        let mut d = Matrix::zeros(5, 5);
        for i in 0..5 {
            d[(i, i)] = ev[i];
        }
        let rec = v.matmul(&d).matmul_nt(&v);
        assert!((&rec - &a).max_abs() < 1e-9, "reconstruction error {:?}", (&rec - &a).max_abs());
    }

    #[test]
    fn eigenvector_orthonormality() {
        let b = Matrix::from_fn(6, 6, |r, c| ((r + 3 * c) as f64 * 0.29).cos());
        let mut a = b.matmul_nt(&b);
        a.symmetrize();
        let (_, v) = jacobi_eigh(&a, true);
        let v = v.unwrap();
        let vtv = v.transpose().matmul(&v);
        assert!((&vtv - &Matrix::eye(6)).max_abs() < 1e-10);
    }

    #[test]
    fn trace_and_det_invariants() {
        let b = Matrix::from_fn(4, 4, |r, c| ((r * 4 + c) as f64 * 0.41).sin());
        let mut a = b.matmul_nt(&b);
        for i in 0..4 {
            a[(i, i)] += 4.0;
        }
        let ev = jacobi_eigenvalues(&a);
        let tr: f64 = ev.iter().sum();
        assert!((tr - a.trace()).abs() < 1e-9);
        let logdet_eig: f64 = ev.iter().map(|v| v.ln()).sum();
        let logdet_chol = Cholesky::new(&a).unwrap().logdet();
        assert!((logdet_eig - logdet_chol).abs() < 1e-8);
    }

    #[test]
    fn rank_probe_detects_singularity() {
        // Rank-2 matrix of size 4.
        let b = Matrix::from_fn(4, 2, |r, c| ((r * 2 + c) as f64 + 1.0).sqrt());
        let a = b.matmul_nt(&b);
        assert_eq!(symmetric_rank(&a, 1e-10), 2);
        // Full-rank SPD.
        let mut full = a.clone();
        for i in 0..4 {
            full[(i, i)] += 1.0;
        }
        assert_eq!(symmetric_rank(&full, 1e-10), 4);
    }
}
