//! Row-major dense `f64` matrix.

use std::fmt;
use std::ops::{Add, Index, IndexMut, Mul, Sub};

/// Dense row-major matrix of `f64`.
///
/// Indexing is `(row, col)`. The representation is a flat `Vec<f64>` of
/// length `rows * cols`; `data[r * cols + c]` holds entry `(r, c)`.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    /// All-zeros matrix of shape `rows × cols`.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix of size `n × n`.
    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a flat row-major slice. Panics if the length mismatches.
    pub fn from_flat(rows: usize, cols: usize, data: &[f64]) -> Self {
        assert_eq!(data.len(), rows * cols, "flat data length mismatch");
        Matrix { rows, cols, data: data.to_vec() }
    }

    /// Take ownership of a flat row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "flat data length mismatch");
        Matrix { rows, cols, data }
    }

    /// Build from nested rows (test convenience).
    pub fn from_rows(rows: &[&[f64]]) -> Self {
        let r = rows.len();
        let c = if r == 0 { 0 } else { rows[0].len() };
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Matrix { rows: r, cols: c, data }
    }

    /// Build an `n × n` matrix from an entry-generating closure.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut m = Self::zeros(rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                m[(r, c)] = f(r, c);
            }
        }
        m
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn is_square(&self) -> bool {
        self.rows == self.cols
    }

    /// Borrow the flat row-major storage.
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    /// Mutably borrow the flat row-major storage.
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Borrow row `r` as a contiguous slice.
    pub fn row(&self, r: usize) -> &[f64] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Copy column `c` out into a `Vec`.
    pub fn col(&self, c: usize) -> Vec<f64> {
        (0..self.rows).map(|r| self[(r, c)]).collect()
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                t[(c, r)] = self[(r, c)];
            }
        }
        t
    }

    /// Matrix-vector product `self · x`.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols, "matvec shape mismatch");
        let mut y = vec![0.0; self.rows];
        for r in 0..self.rows {
            let row = self.row(r);
            let mut acc = 0.0;
            for (a, b) in row.iter().zip(x.iter()) {
                acc += a * b;
            }
            y[r] = acc;
        }
        y
    }

    /// Transposed matrix-vector product `selfᵀ · x`.
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.rows, "matvec_t shape mismatch");
        let mut y = vec![0.0; self.cols];
        for r in 0..self.rows {
            let row = self.row(r);
            let xr = x[r];
            for (yc, a) in y.iter_mut().zip(row.iter()) {
                *yc += a * xr;
            }
        }
        y
    }

    /// Matrix product `self · other` with a blocked ikj loop (cache-friendly).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Matrix::zeros(m, n);
        for i in 0..m {
            for p in 0..k {
                let a = self.data[i * k + p];
                if a == 0.0 {
                    continue;
                }
                let brow = &other.data[p * n..(p + 1) * n];
                let orow = &mut out.data[i * n..(i + 1) * n];
                for (o, b) in orow.iter_mut().zip(brow.iter()) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// `self · otherᵀ` without materializing the transpose.
    pub fn matmul_nt(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols, "matmul_nt shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.rows);
        let mut out = Matrix::zeros(m, n);
        for i in 0..m {
            let arow = self.row(i);
            for j in 0..n {
                let brow = other.row(j);
                let mut acc = 0.0;
                for p in 0..k {
                    acc += arow[p] * brow[p];
                }
                out[(i, j)] = acc;
            }
        }
        out
    }

    /// Scale all entries in place.
    pub fn scale(&mut self, a: f64) {
        for v in &mut self.data {
            *v *= a;
        }
    }

    /// Frobenius norm.
    pub fn frobenius_norm(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    /// Maximum absolute entry (used for Fig. 3's max-error metric).
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0_f64, |m, v| m.max(v.abs()))
    }

    /// Mean absolute entry (used for Fig. 3's MAE metric).
    pub fn mean_abs(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().map(|v| v.abs()).sum::<f64>() / self.data.len() as f64
    }

    /// Trace. Panics on non-square input.
    pub fn trace(&self) -> f64 {
        assert!(self.is_square(), "trace of non-square matrix");
        (0..self.rows).map(|i| self[(i, i)]).sum()
    }

    /// Symmetrize in place: `A ← (A + Aᵀ)/2`. Useful to scrub round-off
    /// asymmetry before Cholesky/eigendecomposition.
    pub fn symmetrize(&mut self) {
        assert!(self.is_square(), "symmetrize of non-square matrix");
        for r in 0..self.rows {
            for c in (r + 1)..self.cols {
                let v = 0.5 * (self[(r, c)] + self[(c, r)]);
                self[(r, c)] = v;
                self[(c, r)] = v;
            }
        }
    }

    /// Maximum absolute asymmetry `max |A - Aᵀ|`.
    pub fn asymmetry(&self) -> f64 {
        assert!(self.is_square());
        let mut m = 0.0_f64;
        for r in 0..self.rows {
            for c in (r + 1)..self.cols {
                m = m.max((self[(r, c)] - self[(c, r)]).abs());
            }
        }
        m
    }
}

impl Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (r, c): (usize, usize)) -> &f64 {
        debug_assert!(r < self.rows && c < self.cols);
        &self.data[r * self.cols + c]
    }
}

impl IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f64 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }
}

impl Add for &Matrix {
    type Output = Matrix;
    fn add(self, rhs: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (rhs.rows, rhs.cols));
        let data = self.data.iter().zip(&rhs.data).map(|(a, b)| a + b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }
}

impl Sub for &Matrix {
    type Output = Matrix;
    fn sub(self, rhs: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (rhs.rows, rhs.cols));
        let data = self.data.iter().zip(&rhs.data).map(|(a, b)| a - b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }
}

impl Mul for &Matrix {
    type Output = Matrix;
    fn mul(self, rhs: &Matrix) -> Matrix {
        self.matmul(rhs)
    }
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        let show = self.rows.min(8);
        for r in 0..show {
            write!(f, "  ")?;
            let cshow = self.cols.min(8);
            for c in 0..cshow {
                write!(f, "{:>11.4e} ", self[(r, c)])?;
            }
            if self.cols > cshow {
                write!(f, "…")?;
            }
            writeln!(f)?;
        }
        if self.rows > show {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_matmul_is_noop() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let i = Matrix::eye(2);
        assert_eq!(a.matmul(&i), a);
        assert_eq!(i.matmul(&a), a);
    }

    #[test]
    fn matmul_known_values() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let b = Matrix::from_rows(&[&[5.0, 6.0], &[7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c, Matrix::from_rows(&[&[19.0, 22.0], &[43.0, 50.0]]));
    }

    #[test]
    fn matmul_nt_matches_explicit_transpose() {
        let a = Matrix::from_fn(3, 4, |r, c| (r * 7 + c) as f64 * 0.3 - 1.0);
        let b = Matrix::from_fn(5, 4, |r, c| (r + 2 * c) as f64 * 0.1);
        let via_nt = a.matmul_nt(&b);
        let via_t = a.matmul(&b.transpose());
        assert!((&via_nt - &via_t).max_abs() < 1e-12);
    }

    #[test]
    fn matvec_matches_matmul() {
        let a = Matrix::from_fn(4, 3, |r, c| (r as f64) - (c as f64) * 0.5);
        let x = vec![1.0, -2.0, 0.5];
        let xm = Matrix::from_vec(3, 1, x.clone());
        let want = a.matmul(&xm);
        let got = a.matvec(&x);
        for (g, w) in got.iter().zip(want.as_slice()) {
            assert!((g - w).abs() < 1e-14);
        }
    }

    #[test]
    fn matvec_t_matches_transpose_matvec() {
        let a = Matrix::from_fn(4, 3, |r, c| ((r * 3 + c) as f64).sin());
        let x = vec![0.3, -1.1, 2.2, 0.7];
        let got = a.matvec_t(&x);
        let want = a.transpose().matvec(&x);
        for (g, w) in got.iter().zip(want.iter()) {
            assert!((g - w).abs() < 1e-14);
        }
    }

    #[test]
    fn transpose_involution() {
        let a = Matrix::from_fn(3, 5, |r, c| (r * 5 + c) as f64);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn norms_and_trace() {
        let a = Matrix::from_rows(&[&[3.0, 0.0], &[0.0, -4.0]]);
        assert!((a.frobenius_norm() - 5.0).abs() < 1e-14);
        assert!((a.trace() + 1.0).abs() < 1e-14);
        assert!((a.max_abs() - 4.0).abs() < 1e-14);
        assert!((a.mean_abs() - 7.0 / 4.0).abs() < 1e-14);
    }

    #[test]
    fn symmetrize_removes_asymmetry() {
        let mut a = Matrix::from_rows(&[&[1.0, 2.0], &[4.0, 1.0]]);
        assert!(a.asymmetry() > 0.0);
        a.symmetrize();
        assert_eq!(a.asymmetry(), 0.0);
        assert!((a[(0, 1)] - 3.0).abs() < 1e-14);
    }

    #[test]
    #[should_panic(expected = "matmul shape mismatch")]
    fn matmul_shape_mismatch_panics() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(2, 3);
        let _ = a.matmul(&b);
    }
}
