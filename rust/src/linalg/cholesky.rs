//! Cholesky factorization of symmetric positive-definite matrices.
//!
//! This is the workhorse of the generative GP view (paper §3.2): the base
//! level of ICR draws `s⁽⁰⁾ = chol(K⁽⁰⁾)·ξ`, and every refinement matrix
//! `√D` (paper Eq. 9) is the Cholesky factor of the conditional covariance
//! `D = K_ff − K_fc K_cc⁻¹ K_cf` (Eq. 8). It is also how the evaluation
//! computes exact log-determinants and KL divergences (Fig. 3, §5.1 table).

use super::matrix::Matrix;
use super::solve::{solve_lower, solve_lower_transpose};

/// Error raised when a matrix is not numerically positive definite.
#[derive(Debug, Clone, PartialEq)]
pub struct NotPositiveDefinite {
    /// Pivot index where the factorization broke down.
    pub pivot: usize,
    /// Value of the offending diagonal element.
    pub value: f64,
}

impl std::fmt::Display for NotPositiveDefinite {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "matrix not positive definite: pivot {} has value {:.3e}", self.pivot, self.value)
    }
}

impl std::error::Error for NotPositiveDefinite {}

/// Lower-triangular Cholesky factor `L` with `L·Lᵀ = A`.
#[derive(Debug, Clone)]
pub struct Cholesky {
    l: Matrix,
}

impl Cholesky {
    /// Factor a symmetric positive-definite matrix.
    ///
    /// Only the lower triangle of `a` is read. Returns
    /// [`NotPositiveDefinite`] if a pivot is ≤ 0 (up to a tiny tolerance),
    /// which doubles as the rank probe for the §5.2 full-rank claim.
    pub fn new(a: &Matrix) -> Result<Self, NotPositiveDefinite> {
        Self::new_with_jitter(a, 0.0)
    }

    /// Factor `a + jitter·I`. A small diagonal jitter is the classical fix
    /// for covariance matrices that are PSD up to round-off; KISS-GP needs
    /// it to be invertible at all (paper §5.2), ICR does not.
    pub fn new_with_jitter(a: &Matrix, jitter: f64) -> Result<Self, NotPositiveDefinite> {
        assert!(a.is_square(), "cholesky of non-square matrix");
        let n = a.rows();
        let mut l = Matrix::zeros(n, n);
        for j in 0..n {
            // Diagonal element.
            let mut d = a[(j, j)] + jitter;
            for k in 0..j {
                let v = l[(j, k)];
                d -= v * v;
            }
            if d <= 0.0 || !d.is_finite() {
                return Err(NotPositiveDefinite { pivot: j, value: d });
            }
            let dj = d.sqrt();
            l[(j, j)] = dj;
            // Column below the diagonal.
            for i in (j + 1)..n {
                let mut s = a[(i, j)];
                for k in 0..j {
                    s -= l[(i, k)] * l[(j, k)];
                }
                l[(i, j)] = s / dj;
            }
        }
        Ok(Cholesky { l })
    }

    /// The lower-triangular factor.
    pub fn l(&self) -> &Matrix {
        &self.l
    }

    /// Consume and return the factor.
    pub fn into_l(self) -> Matrix {
        self.l
    }

    /// Dimension of the factored matrix.
    pub fn dim(&self) -> usize {
        self.l.rows()
    }

    /// `log|A| = 2·Σ log L_ii`.
    pub fn logdet(&self) -> f64 {
        let n = self.l.rows();
        2.0 * (0..n).map(|i| self.l[(i, i)].ln()).sum::<f64>()
    }

    /// Solve `A·x = b` via forward+back substitution.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let y = solve_lower(&self.l, b);
        solve_lower_transpose(&self.l, &y)
    }

    /// Solve `A·X = B` column-wise.
    pub fn solve_matrix(&self, b: &Matrix) -> Matrix {
        let n = self.dim();
        assert_eq!(b.rows(), n, "solve_matrix shape mismatch");
        let mut out = Matrix::zeros(n, b.cols());
        for c in 0..b.cols() {
            let col = b.col(c);
            let x = self.solve(&col);
            for r in 0..n {
                out[(r, c)] = x[r];
            }
        }
        out
    }

    /// Inverse of the factored matrix (dense; test/evaluation use only).
    pub fn inverse(&self) -> Matrix {
        self.solve_matrix(&Matrix::eye(self.dim()))
    }

    /// Apply the factor: `L·x` — this is exactly "applying the square root
    /// of the kernel matrix" in the paper's sense for the dense reference.
    pub fn apply_sqrt(&self, x: &[f64]) -> Vec<f64> {
        let n = self.dim();
        assert_eq!(x.len(), n);
        let mut y = vec![0.0; n];
        for i in 0..n {
            let row = self.l.row(i);
            let mut acc = 0.0;
            for j in 0..=i {
                acc += row[j] * x[j];
            }
            y[i] = acc;
        }
        y
    }

    /// Apply the transposed factor: `Lᵀ·x` — the adjoint of
    /// [`Self::apply_sqrt`], needed for backpropagating through the dense
    /// generative model (mirrors `IcrEngine::apply_sqrt_transpose`).
    pub fn apply_sqrt_transpose(&self, x: &[f64]) -> Vec<f64> {
        let n = self.dim();
        assert_eq!(x.len(), n);
        let mut y = vec![0.0; n];
        for j in 0..n {
            let mut acc = 0.0;
            for i in j..n {
                acc += self.l[(i, j)] * x[i];
            }
            y[j] = acc;
        }
        y
    }

    /// Apply the factor to a flat row-major `batch × n` panel in one
    /// triangular panel sweep: `L` is streamed once per lane *block*
    /// (up to [`crate::parallel::MAX_LANES`] interleaved lanes) instead
    /// of once per lane. Bit-for-bit identical to stacking
    /// [`Self::apply_sqrt`].
    pub fn apply_sqrt_panel(&self, panel: &[f64], batch: usize) -> Vec<f64> {
        let mut out = vec![0.0; batch * self.dim()];
        self.apply_sqrt_panel_into(panel, batch, &mut out);
        out
    }

    /// [`Self::apply_sqrt_panel`] writing into caller-provided storage.
    /// Uses the AVX2 microkernels when the process-wide SIMD dispatch is
    /// on (`crate::parallel::simd_enabled`); results are bit-identical
    /// either way.
    pub fn apply_sqrt_panel_into(&self, panel: &[f64], batch: usize, out: &mut [f64]) {
        self.panel_apply(panel, batch, out, false, crate::parallel::simd_enabled());
    }

    /// [`Self::apply_sqrt_panel_into`] with an explicit SIMD selection
    /// (engines pin the policy once at model build; `true` is still
    /// subject to hardware support).
    pub fn apply_sqrt_panel_into_with(
        &self,
        panel: &[f64],
        batch: usize,
        out: &mut [f64],
        simd: bool,
    ) {
        self.panel_apply(panel, batch, out, false, simd && crate::parallel::simd_supported());
    }

    /// Adjoint panel apply `Lᵀ·X` over a flat row-major `batch × n`
    /// panel; bit-for-bit identical to stacking
    /// [`Self::apply_sqrt_transpose`].
    pub fn apply_sqrt_transpose_panel(&self, panel: &[f64], batch: usize) -> Vec<f64> {
        let mut out = vec![0.0; batch * self.dim()];
        self.apply_sqrt_transpose_panel_into(panel, batch, &mut out);
        out
    }

    /// [`Self::apply_sqrt_transpose_panel`] writing into caller storage.
    pub fn apply_sqrt_transpose_panel_into(&self, panel: &[f64], batch: usize, out: &mut [f64]) {
        self.panel_apply(panel, batch, out, true, crate::parallel::simd_enabled());
    }

    /// [`Self::apply_sqrt_transpose_panel_into`] with an explicit SIMD
    /// selection (see [`Self::apply_sqrt_panel_into_with`]).
    pub fn apply_sqrt_transpose_panel_into_with(
        &self,
        panel: &[f64],
        batch: usize,
        out: &mut [f64],
        simd: bool,
    ) {
        self.panel_apply(panel, batch, out, true, simd && crate::parallel::simd_supported());
    }

    fn panel_apply(
        &self,
        panel: &[f64],
        batch: usize,
        out: &mut [f64],
        transpose: bool,
        simd: bool,
    ) {
        let n = self.dim();
        assert_eq!(panel.len(), batch * n, "panel length mismatch");
        assert_eq!(out.len(), batch * n, "output panel length mismatch");
        let l = self.l.as_slice();
        // One staging buffer, sized for the widest lane block of this call.
        let mut x_il = vec![0.0; n * crate::parallel::lane_block(batch.max(1))];
        let mut b0 = 0usize;
        while b0 < batch {
            let nb = crate::parallel::lane_block(batch - b0);
            let stage = &mut x_il[..n * nb];
            #[cfg(target_arch = "x86_64")]
            if simd && nb == 8 {
                // SAFETY: `simd` is only true when AVX2 was detected
                // (`parallel::simd_supported`, ANDed in by every caller).
                unsafe { simd::tri_panel_x8(l, n, panel, b0, stage, out, transpose) };
                b0 += nb;
                continue;
            } else if simd && nb == 4 {
                unsafe { simd::tri_panel_x4(l, n, panel, b0, stage, out, transpose) };
                b0 += nb;
                continue;
            }
            #[cfg(not(target_arch = "x86_64"))]
            let _ = simd;
            match nb {
                1 => tri_panel_block::<1>(l, n, panel, b0, stage, out, transpose),
                2 => tri_panel_block::<2>(l, n, panel, b0, stage, out, transpose),
                4 => tri_panel_block::<4>(l, n, panel, b0, stage, out, transpose),
                _ => tri_panel_block::<8>(l, n, panel, b0, stage, out, transpose),
            }
            b0 += nb;
        }
    }
}

/// AVX2 variants of the triangular panel sweep for the 8- and 4-lane
/// blocks. Broadcast-mul then add — never fused — in the scalar kernel's
/// exact accumulation order, so the results are bit-for-bit identical to
/// [`tri_panel_block`] (enforced by the tests below and
/// `rust/tests/panel_equivalence.rs`).
#[cfg(target_arch = "x86_64")]
#[allow(clippy::needless_range_loop)] // indexed lane loops keep the order explicit
mod simd {
    use std::arch::x86_64::*;

    /// # Safety
    /// Caller must have verified AVX2 support at runtime.
    #[target_feature(enable = "avx2")]
    #[allow(clippy::missing_safety_doc)]
    pub(super) unsafe fn tri_panel_x8(
        l: &[f64],
        n: usize,
        panel: &[f64],
        b0: usize,
        x_il: &mut [f64],
        out: &mut [f64],
        transpose: bool,
    ) {
        const NB: usize = 8;
        debug_assert_eq!(x_il.len(), n * NB);
        for i in 0..n {
            for q in 0..NB {
                x_il[i * NB + q] = panel[(b0 + q) * n + i];
            }
        }
        let mut tmp = [0.0f64; NB];
        if transpose {
            for j in 0..n {
                let mut acc0 = _mm256_setzero_pd();
                let mut acc1 = _mm256_setzero_pd();
                for i in j..n {
                    let lij = _mm256_set1_pd(l[i * n + j]);
                    let p = x_il.as_ptr().add(i * NB);
                    acc0 = _mm256_add_pd(acc0, _mm256_mul_pd(lij, _mm256_loadu_pd(p)));
                    acc1 = _mm256_add_pd(acc1, _mm256_mul_pd(lij, _mm256_loadu_pd(p.add(4))));
                }
                _mm256_storeu_pd(tmp.as_mut_ptr(), acc0);
                _mm256_storeu_pd(tmp.as_mut_ptr().add(4), acc1);
                for q in 0..NB {
                    out[(b0 + q) * n + j] = tmp[q];
                }
            }
        } else {
            for i in 0..n {
                let mut acc0 = _mm256_setzero_pd();
                let mut acc1 = _mm256_setzero_pd();
                for j in 0..=i {
                    let lij = _mm256_set1_pd(l[i * n + j]);
                    let p = x_il.as_ptr().add(j * NB);
                    acc0 = _mm256_add_pd(acc0, _mm256_mul_pd(lij, _mm256_loadu_pd(p)));
                    acc1 = _mm256_add_pd(acc1, _mm256_mul_pd(lij, _mm256_loadu_pd(p.add(4))));
                }
                _mm256_storeu_pd(tmp.as_mut_ptr(), acc0);
                _mm256_storeu_pd(tmp.as_mut_ptr().add(4), acc1);
                for q in 0..NB {
                    out[(b0 + q) * n + i] = tmp[q];
                }
            }
        }
    }

    /// # Safety
    /// Caller must have verified AVX2 support at runtime.
    #[target_feature(enable = "avx2")]
    #[allow(clippy::missing_safety_doc)]
    pub(super) unsafe fn tri_panel_x4(
        l: &[f64],
        n: usize,
        panel: &[f64],
        b0: usize,
        x_il: &mut [f64],
        out: &mut [f64],
        transpose: bool,
    ) {
        const NB: usize = 4;
        debug_assert_eq!(x_il.len(), n * NB);
        for i in 0..n {
            for q in 0..NB {
                x_il[i * NB + q] = panel[(b0 + q) * n + i];
            }
        }
        let mut tmp = [0.0f64; NB];
        if transpose {
            for j in 0..n {
                let mut acc = _mm256_setzero_pd();
                for i in j..n {
                    let lij = _mm256_set1_pd(l[i * n + j]);
                    acc = _mm256_add_pd(
                        acc,
                        _mm256_mul_pd(lij, _mm256_loadu_pd(x_il.as_ptr().add(i * NB))),
                    );
                }
                _mm256_storeu_pd(tmp.as_mut_ptr(), acc);
                for q in 0..NB {
                    out[(b0 + q) * n + j] = tmp[q];
                }
            }
        } else {
            for i in 0..n {
                let mut acc = _mm256_setzero_pd();
                for j in 0..=i {
                    let lij = _mm256_set1_pd(l[i * n + j]);
                    acc = _mm256_add_pd(
                        acc,
                        _mm256_mul_pd(lij, _mm256_loadu_pd(x_il.as_ptr().add(j * NB))),
                    );
                }
                _mm256_storeu_pd(tmp.as_mut_ptr(), acc);
                for q in 0..NB {
                    out[(b0 + q) * n + i] = tmp[q];
                }
            }
        }
    }
}

/// One interleaved lane block of `L·X` (or `Lᵀ·X`): load each `L` element
/// once, contract against all `NB` lanes. Per-lane accumulation order
/// matches the single-vector applies exactly.
#[allow(clippy::needless_range_loop)] // indexed lane loops keep the order explicit
fn tri_panel_block<const NB: usize>(
    l: &[f64],
    n: usize,
    panel: &[f64],
    b0: usize,
    x_il: &mut [f64],
    out: &mut [f64],
    transpose: bool,
) {
    // Stage the block lane-interleaved so the inner loops are contiguous.
    debug_assert_eq!(x_il.len(), n * NB);
    for i in 0..n {
        for q in 0..NB {
            x_il[i * NB + q] = panel[(b0 + q) * n + i];
        }
    }
    if transpose {
        for j in 0..n {
            let mut acc = [0.0f64; NB];
            for i in j..n {
                let lij = l[i * n + j];
                let xv = &x_il[i * NB..(i + 1) * NB];
                for q in 0..NB {
                    acc[q] += lij * xv[q];
                }
            }
            for q in 0..NB {
                out[(b0 + q) * n + j] = acc[q];
            }
        }
    } else {
        for i in 0..n {
            let row = &l[i * n..i * n + i + 1];
            let mut acc = [0.0f64; NB];
            for (j, &lij) in row.iter().enumerate() {
                let xv = &x_il[j * NB..(j + 1) * NB];
                for q in 0..NB {
                    acc[q] += lij * xv[q];
                }
            }
            for q in 0..NB {
                out[(b0 + q) * n + i] = acc[q];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spd_matrix(n: usize) -> Matrix {
        // A = B·Bᵀ + n·I is SPD for any B.
        let b = Matrix::from_fn(n, n, |r, c| ((r * n + c) as f64 * 0.37).sin());
        let mut a = b.matmul_nt(&b);
        for i in 0..n {
            a[(i, i)] += n as f64;
        }
        a
    }

    #[test]
    fn factor_roundtrip() {
        let a = spd_matrix(6);
        let ch = Cholesky::new(&a).unwrap();
        let rec = ch.l().matmul_nt(ch.l());
        assert!((&rec - &a).max_abs() < 1e-10);
    }

    #[test]
    fn logdet_matches_2x2_analytic() {
        let a = Matrix::from_rows(&[&[4.0, 2.0], &[2.0, 3.0]]);
        let ch = Cholesky::new(&a).unwrap();
        // det = 12 - 4 = 8
        assert!((ch.logdet() - 8.0_f64.ln()).abs() < 1e-12);
    }

    #[test]
    fn solve_recovers_known_solution() {
        let a = spd_matrix(5);
        let x_true = vec![1.0, -2.0, 3.0, 0.5, -0.25];
        let b = a.matvec(&x_true);
        let ch = Cholesky::new(&a).unwrap();
        let x = ch.solve(&b);
        for (xi, ti) in x.iter().zip(&x_true) {
            assert!((xi - ti).abs() < 1e-9, "{xi} vs {ti}");
        }
    }

    #[test]
    fn inverse_times_matrix_is_identity() {
        let a = spd_matrix(4);
        let inv = Cholesky::new(&a).unwrap().inverse();
        let id = a.matmul(&inv);
        assert!((&id - &Matrix::eye(4)).max_abs() < 1e-9);
    }

    #[test]
    fn apply_sqrt_matches_matvec_on_factor() {
        let a = spd_matrix(5);
        let ch = Cholesky::new(&a).unwrap();
        let x = vec![0.1, 0.2, -0.3, 0.4, -0.5];
        let got = ch.apply_sqrt(&x);
        let want = ch.l().matvec(&x);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-13);
        }
    }

    #[test]
    fn apply_sqrt_transpose_satisfies_adjoint_identity() {
        // ⟨L·x, y⟩ = ⟨x, Lᵀ·y⟩ for random-ish x, y.
        let a = spd_matrix(6);
        let ch = Cholesky::new(&a).unwrap();
        let x: Vec<f64> = (0..6).map(|i| ((i * 7) as f64 * 0.13).sin()).collect();
        let y: Vec<f64> = (0..6).map(|i| ((i * 3) as f64 * 0.29).cos()).collect();
        let lx = ch.apply_sqrt(&x);
        let lty = ch.apply_sqrt_transpose(&y);
        let lhs: f64 = lx.iter().zip(&y).map(|(a, b)| a * b).sum();
        let rhs: f64 = x.iter().zip(&lty).map(|(a, b)| a * b).sum();
        assert!((lhs - rhs).abs() < 1e-12, "{lhs} vs {rhs}");
    }

    #[test]
    fn panel_applies_match_stacked_singles_bitwise() {
        let a = spd_matrix(9);
        let ch = Cholesky::new(&a).unwrap();
        let n = ch.dim();
        for batch in [1usize, 3, 8, 11] {
            let panel: Vec<f64> =
                (0..batch * n).map(|k| ((k * 13) as f64 * 0.071).sin() * 2.0).collect();
            let fwd = ch.apply_sqrt_panel(&panel, batch);
            let bwd = ch.apply_sqrt_transpose_panel(&panel, batch);
            for b in 0..batch {
                let lane = &panel[b * n..(b + 1) * n];
                let want_f = ch.apply_sqrt(lane);
                let want_b = ch.apply_sqrt_transpose(lane);
                for i in 0..n {
                    assert_eq!(fwd[b * n + i].to_bits(), want_f[i].to_bits(), "fwd b{b} i{i}");
                    assert_eq!(bwd[b * n + i].to_bits(), want_b[i].to_bits(), "bwd b{b} i{i}");
                }
            }
        }
    }

    #[test]
    fn simd_and_scalar_panel_sweeps_agree_bitwise() {
        // Force the SIMD and scalar paths explicitly; on CPUs without
        // AVX2 both calls run scalar and the assertion is trivially true.
        let a = spd_matrix(17);
        let ch = Cholesky::new(&a).unwrap();
        let n = ch.dim();
        for batch in [4usize, 8, 12, 9] {
            let panel: Vec<f64> =
                (0..batch * n).map(|k| ((k * 7) as f64 * 0.093).sin() * 1.5).collect();
            let mut scalar_f = vec![0.0; batch * n];
            let mut simd_f = vec![0.0; batch * n];
            ch.apply_sqrt_panel_into_with(&panel, batch, &mut scalar_f, false);
            ch.apply_sqrt_panel_into_with(&panel, batch, &mut simd_f, true);
            assert!(scalar_f.iter().zip(&simd_f).all(|(x, y)| x.to_bits() == y.to_bits()));
            let mut scalar_b = vec![0.0; batch * n];
            let mut simd_b = vec![0.0; batch * n];
            ch.apply_sqrt_transpose_panel_into_with(&panel, batch, &mut scalar_b, false);
            ch.apply_sqrt_transpose_panel_into_with(&panel, batch, &mut simd_b, true);
            assert!(scalar_b.iter().zip(&simd_b).all(|(x, y)| x.to_bits() == y.to_bits()));
        }
    }

    #[test]
    fn indefinite_matrix_rejected() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[2.0, 1.0]]); // eigenvalues 3, -1
        let err = Cholesky::new(&a).unwrap_err();
        assert_eq!(err.pivot, 1);
        assert!(err.value <= 0.0);
    }

    #[test]
    fn jitter_rescues_singular_matrix() {
        // Rank-1 matrix: singular without jitter.
        let a = Matrix::from_rows(&[&[1.0, 1.0], &[1.0, 1.0]]);
        assert!(Cholesky::new(&a).is_err());
        assert!(Cholesky::new_with_jitter(&a, 1e-6).is_ok());
    }

    #[test]
    fn sample_covariance_statistics() {
        // L·ξ with ξ ~ N(0,1) must reproduce A in expectation; check with a
        // deterministic quadrature over ±unit vectors instead of RNG:
        // Σ_i (L e_i)(L e_i)ᵀ = L Lᵀ = A.
        let a = spd_matrix(4);
        let ch = Cholesky::new(&a).unwrap();
        let mut acc = Matrix::zeros(4, 4);
        for i in 0..4 {
            let mut e = vec![0.0; 4];
            e[i] = 1.0;
            let s = ch.apply_sqrt(&e);
            for r in 0..4 {
                for c in 0..4 {
                    acc[(r, c)] += s[r] * s[c];
                }
            }
        }
        assert!((&acc - &a).max_abs() < 1e-10);
    }
}
