//! Triangular solves.

use super::matrix::Matrix;

/// Solve `L·y = b` for lower-triangular `L` (forward substitution).
pub fn solve_lower(l: &Matrix, b: &[f64]) -> Vec<f64> {
    let n = l.rows();
    assert!(l.is_square() && b.len() == n, "solve_lower shape mismatch");
    let mut y = vec![0.0; n];
    for i in 0..n {
        let row = l.row(i);
        let mut acc = b[i];
        for j in 0..i {
            acc -= row[j] * y[j];
        }
        y[i] = acc / row[i];
    }
    y
}

/// Solve `Lᵀ·x = b` for lower-triangular `L` (back substitution on the
/// transpose, without materializing it).
pub fn solve_lower_transpose(l: &Matrix, b: &[f64]) -> Vec<f64> {
    let n = l.rows();
    assert!(l.is_square() && b.len() == n, "solve_lower_transpose shape mismatch");
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut acc = b[i];
        for j in (i + 1)..n {
            acc -= l[(j, i)] * x[j];
        }
        x[i] = acc / l[(i, i)];
    }
    x
}

/// Solve `U·x = b` for upper-triangular `U` (back substitution).
pub fn solve_upper(u: &Matrix, b: &[f64]) -> Vec<f64> {
    let n = u.rows();
    assert!(u.is_square() && b.len() == n, "solve_upper shape mismatch");
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let row = u.row(i);
        let mut acc = b[i];
        for j in (i + 1)..n {
            acc -= row[j] * x[j];
        }
        x[i] = acc / row[i];
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lower(n: usize) -> Matrix {
        Matrix::from_fn(n, n, |r, c| {
            if c > r {
                0.0
            } else if c == r {
                2.0 + r as f64
            } else {
                ((r + 2 * c) as f64 * 0.31).cos()
            }
        })
    }

    #[test]
    fn forward_substitution() {
        let l = lower(6);
        let x_true: Vec<f64> = (0..6).map(|i| (i as f64) - 2.5).collect();
        let b = l.matvec(&x_true);
        let x = solve_lower(&l, &b);
        for (a, t) in x.iter().zip(&x_true) {
            assert!((a - t).abs() < 1e-12);
        }
    }

    #[test]
    fn transpose_back_substitution() {
        let l = lower(6);
        let x_true: Vec<f64> = (0..6).map(|i| ((i * i) as f64).sin()).collect();
        let b = l.transpose().matvec(&x_true);
        let x = solve_lower_transpose(&l, &b);
        for (a, t) in x.iter().zip(&x_true) {
            assert!((a - t).abs() < 1e-12);
        }
    }

    #[test]
    fn upper_back_substitution() {
        let u = lower(5).transpose();
        let x_true = vec![1.0, 2.0, -1.0, 0.5, 3.0];
        let b = u.matvec(&x_true);
        let x = solve_upper(&u, &b);
        for (a, t) in x.iter().zip(&x_true) {
            assert!((a - t).abs() < 1e-12);
        }
    }

    #[test]
    fn solve_upper_equals_solve_lower_transpose() {
        let l = lower(4);
        let b = vec![1.0, -1.0, 2.0, 0.0];
        let via_t = solve_lower_transpose(&l, &b);
        let via_u = solve_upper(&l.transpose(), &b);
        for (a, t) in via_t.iter().zip(&via_u) {
            assert!((a - t).abs() < 1e-13);
        }
    }
}
