//! Artifact manifest: the contract between `python/compile/aot.py` (which
//! writes `artifacts/manifest.json`) and the Rust runtime (which loads,
//! compiles and self-checks the artifacts it describes).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::json::Value;

/// Shape + dtype of one executable input or output.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(v: &Value) -> Result<Self> {
        let name = v.get("name").and_then(Value::as_str).unwrap_or("").to_string();
        let shape = v
            .get("shape")
            .and_then(Value::as_array)
            .ok_or_else(|| anyhow!("tensor spec missing shape"))?
            .iter()
            .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim in shape")))
            .collect::<Result<Vec<_>>>()?;
        let dtype = v.get("dtype").and_then(Value::as_str).unwrap_or("f64").to_string();
        Ok(TensorSpec { name, shape, dtype })
    }
}

/// Expected output for a deterministic validation excitation — lets the
/// runtime prove, after compiling, that the artifact computes the same
/// numbers the Python build did.
#[derive(Debug, Clone)]
pub struct Validation {
    pub out_head: Vec<f64>,
    pub out_l2: f64,
}

/// One AOT-compiled model variant.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub meta: Value,
    pub validation: Option<Validation>,
}

impl ArtifactSpec {
    /// Metadata accessor with type coercion.
    pub fn meta_usize(&self, key: &str) -> Option<usize> {
        self.meta.get(key).and_then(Value::as_usize)
    }

    pub fn meta_f64(&self, key: &str) -> Option<f64> {
        self.meta.get(key).and_then(Value::as_f64)
    }

    pub fn meta_str(&self, key: &str) -> Option<&str> {
        self.meta.get(key).and_then(Value::as_str)
    }

    pub fn kind(&self) -> &str {
        self.meta_str("kind").unwrap_or("unknown")
    }
}

/// The parsed `artifacts/manifest.json` plus its directory.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub version: usize,
    pub dtype: String,
    pub lanczos_probes: usize,
    artifacts: BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    /// Load and validate `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts` first)", path.display()))?;
        let root = Value::parse(&text).context("parsing manifest.json")?;
        let version = root.get("version").and_then(Value::as_usize).unwrap_or(0);
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        let dtype = root.get("dtype").and_then(Value::as_str).unwrap_or("f64").to_string();
        let lanczos_probes = root.get("lanczos_probes").and_then(Value::as_usize).unwrap_or(10);

        let mut artifacts = BTreeMap::new();
        for a in root
            .get("artifacts")
            .and_then(Value::as_array)
            .ok_or_else(|| anyhow!("manifest missing artifacts array"))?
        {
            let name = a
                .get("name")
                .and_then(Value::as_str)
                .ok_or_else(|| anyhow!("artifact missing name"))?
                .to_string();
            let file = a
                .get("file")
                .and_then(Value::as_str)
                .ok_or_else(|| anyhow!("artifact {name} missing file"))?
                .to_string();
            let inputs = a
                .get("inputs")
                .and_then(Value::as_array)
                .ok_or_else(|| anyhow!("artifact {name} missing inputs"))?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            let outputs = a
                .get("outputs")
                .and_then(Value::as_array)
                .ok_or_else(|| anyhow!("artifact {name} missing outputs"))?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            let meta = a.get("meta").cloned().unwrap_or(Value::Null);
            let validation = a.get("validation").map(|v| -> Result<Validation> {
                let out_head = v
                    .get("out_head")
                    .and_then(Value::as_array)
                    .ok_or_else(|| anyhow!("validation missing out_head"))?
                    .iter()
                    .map(|x| x.as_f64().ok_or_else(|| anyhow!("bad out_head value")))
                    .collect::<Result<Vec<_>>>()?;
                let out_l2 = v
                    .get("out_l2")
                    .and_then(Value::as_f64)
                    .ok_or_else(|| anyhow!("validation missing out_l2"))?;
                Ok(Validation { out_head, out_l2 })
            });
            let validation = match validation {
                Some(v) => Some(v?),
                None => None,
            };
            artifacts.insert(
                name.clone(),
                ArtifactSpec { name, file, inputs, outputs, meta, validation },
            );
        }
        Ok(Manifest { dir: dir.to_path_buf(), version, dtype, lanczos_probes, artifacts })
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.artifacts.keys().map(String::as_str)
    }

    pub fn len(&self) -> usize {
        self.artifacts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.artifacts.is_empty()
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not in manifest ({} available)", self.len()))
    }

    /// All artifacts of a given `meta.kind`.
    pub fn by_kind(&self, kind: &str) -> Vec<&ArtifactSpec> {
        self.artifacts.values().filter(|a| a.kind() == kind).collect()
    }

    /// Find the batched ICR apply whose batch is the smallest ≥ `batch`
    /// for the given model size — the router's bucketing rule.
    pub fn best_icr_batch(&self, n: usize, batch: usize) -> Option<&ArtifactSpec> {
        self.artifacts
            .values()
            .filter(|a| a.kind() == "icr" && a.meta_usize("n") == Some(n))
            .filter(|a| a.meta_usize("batch").unwrap_or(1) >= batch)
            .min_by_key(|a| a.meta_usize("batch").unwrap_or(1))
    }

    /// Absolute path of an artifact's HLO file.
    pub fn hlo_path(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_manifest(dir: &Path, body: &str) {
        let mut f = std::fs::File::create(dir.join("manifest.json")).unwrap();
        f.write_all(body.as_bytes()).unwrap();
    }

    fn sample_manifest() -> &'static str {
        r#"{
          "version": 1, "dtype": "f64", "lanczos_probes": 10,
          "artifacts": [
            {"name": "icr_apply_c5f4_n200", "file": "a.hlo.txt",
             "inputs": [{"name": "xi", "shape": [425], "dtype": "f64"}],
             "outputs": [{"name": "s", "shape": [200], "dtype": "f64"}],
             "meta": {"kind": "icr", "n": 200, "dof": 425, "batch": 1},
             "validation": {"out_head": [0.1, 0.2], "out_l2": 14.5}},
            {"name": "icr_apply_batch8", "file": "b.hlo.txt",
             "inputs": [{"name": "xi", "shape": [8, 425], "dtype": "f64"}],
             "outputs": [{"name": "s", "shape": [8, 200], "dtype": "f64"}],
             "meta": {"kind": "icr", "n": 200, "dof": 425, "batch": 8}},
            {"name": "icr_apply_batch32", "file": "c.hlo.txt",
             "inputs": [{"name": "xi", "shape": [32, 425], "dtype": "f64"}],
             "outputs": [{"name": "s", "shape": [32, 200], "dtype": "f64"}],
             "meta": {"kind": "icr", "n": 200, "dof": 425, "batch": 32}},
            {"name": "kissgp_forward_n200", "file": "d.hlo.txt",
             "inputs": [{"name": "y", "shape": [200]}, {"name": "probes", "shape": [10, 200]}],
             "outputs": [{"name": "x", "shape": [200]}, {"name": "logdet", "shape": []}],
             "meta": {"kind": "kissgp", "n": 200}}
          ]
        }"#
    }

    #[test]
    fn load_and_query() {
        let dir = std::env::temp_dir().join(format!("icr_manifest_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        write_manifest(&dir, sample_manifest());
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.len(), 4);
        let a = m.get("icr_apply_c5f4_n200").unwrap();
        assert_eq!(a.inputs[0].shape, vec![425]);
        assert_eq!(a.outputs[0].element_count(), 200);
        assert_eq!(a.kind(), "icr");
        assert_eq!(a.meta_usize("dof"), Some(425));
        let v = a.validation.as_ref().unwrap();
        assert_eq!(v.out_head.len(), 2);
        assert!(m.get("nonexistent").is_err());
        assert_eq!(m.by_kind("kissgp").len(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn batch_bucketing_picks_smallest_fitting() {
        let dir = std::env::temp_dir().join(format!("icr_manifest_bucket_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        write_manifest(&dir, sample_manifest());
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.best_icr_batch(200, 1).unwrap().meta_usize("batch"), Some(1));
        assert_eq!(m.best_icr_batch(200, 2).unwrap().meta_usize("batch"), Some(8));
        assert_eq!(m.best_icr_batch(200, 8).unwrap().meta_usize("batch"), Some(8));
        assert_eq!(m.best_icr_batch(200, 9).unwrap().meta_usize("batch"), Some(32));
        assert!(m.best_icr_batch(200, 33).is_none());
        assert!(m.best_icr_batch(999, 1).is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_is_helpful_error() {
        let err = Manifest::load(Path::new("/nonexistent/dir")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
