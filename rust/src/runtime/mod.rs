//! Runtime: load + execute AOT-compiled XLA artifacts via PJRT.
//!
//! `python/compile/aot.py` lowers every model variant to HLO text once
//! (`make artifacts`); this module compiles those artifacts on the PJRT
//! CPU client and executes them from the L3 hot path. Python never runs
//! at request time.

pub mod executor;
pub mod manifest;
pub mod service;

pub use executor::{Executable, PjrtRuntime};
pub use service::PjrtService;
pub use manifest::{ArtifactSpec, Manifest, TensorSpec, Validation};

use std::path::PathBuf;

/// Default artifact directory (relative to the repo root / CWD).
pub fn default_artifact_dir() -> PathBuf {
    std::env::var_os("ICR_ARTIFACT_DIR").map(PathBuf::from).unwrap_or_else(|| PathBuf::from("artifacts"))
}
