//! Thread-confined PJRT actor.
//!
//! The `xla` crate's PJRT wrappers are `Rc`-based and therefore neither
//! `Send` nor `Sync` — they must live on a single thread. The coordinator,
//! however, is a multi-threaded worker pool. [`PjrtService`] bridges the
//! two with the actor pattern: one dedicated thread owns the
//! [`PjrtRuntime`] (client, compiled executables, cache) and serves
//! execute/self-check commands over an mpsc channel. The handle is cheap
//! to clone, `Send + Sync`, and keeps a *plain-data* copy of the manifest
//! for routing decisions that don't need the runtime.

use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use super::executor::PjrtRuntime;
use super::manifest::Manifest;

enum Command {
    Execute { name: String, inputs: Vec<Vec<f64>>, reply: mpsc::Sender<Result<Vec<Vec<f64>>>> },
    SelfCheck { name: String, reply: mpsc::Sender<Result<()>> },
    Warmup { names: Vec<String>, reply: mpsc::Sender<Result<()>> },
    Platform { reply: mpsc::Sender<String> },
    Shutdown,
}

/// Cloneable, thread-safe handle to a PJRT runtime living on its own
/// thread.
#[derive(Clone)]
pub struct PjrtService {
    tx: Arc<Mutex<mpsc::Sender<Command>>>,
    manifest: Arc<Manifest>,
}

impl PjrtService {
    /// Spawn the actor thread; fails fast if the manifest is unreadable or
    /// the PJRT client cannot be created.
    pub fn start(artifact_dir: &Path) -> Result<PjrtService> {
        // Parse the manifest on the caller thread too — it is plain data
        // and the handle needs it for routing.
        let manifest = Arc::new(Manifest::load(artifact_dir)?);
        let dir: PathBuf = artifact_dir.to_path_buf();
        let (tx, rx) = mpsc::channel::<Command>();
        let (init_tx, init_rx) = mpsc::channel::<Result<()>>();
        std::thread::Builder::new()
            .name("icr-pjrt".into())
            .spawn(move || {
                let runtime = match PjrtRuntime::new(&dir) {
                    Ok(rt) => {
                        let _ = init_tx.send(Ok(()));
                        rt
                    }
                    Err(e) => {
                        let _ = init_tx.send(Err(e));
                        return;
                    }
                };
                for cmd in rx {
                    match cmd {
                        Command::Execute { name, inputs, reply } => {
                            let refs: Vec<&[f64]> = inputs.iter().map(Vec::as_slice).collect();
                            let _ = reply.send(runtime.execute_f64(&name, &refs));
                        }
                        Command::SelfCheck { name, reply } => {
                            let result = runtime
                                .load(&name)
                                .and_then(|exe| exe.self_check())
                                .with_context(|| format!("self-check {name}"));
                            let _ = reply.send(result);
                        }
                        Command::Warmup { names, reply } => {
                            let mut result = Ok(());
                            for n in &names {
                                if let Err(e) = runtime.load(n) {
                                    result = Err(e).with_context(|| format!("warmup {n}"));
                                    break;
                                }
                            }
                            let _ = reply.send(result);
                        }
                        Command::Platform { reply } => {
                            let _ = reply.send(runtime.platform());
                        }
                        Command::Shutdown => break,
                    }
                }
            })
            .context("spawning PJRT actor thread")?;
        init_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("PJRT actor died during init"))??;
        Ok(PjrtService { tx: Arc::new(Mutex::new(tx)), manifest })
    }

    /// Plain-data manifest for routing (no runtime round-trip).
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn send(&self, cmd: Command) {
        // A disconnected actor shows up as RecvError on the reply side.
        let _ = self.tx.lock().unwrap().send(cmd);
    }

    pub fn execute_f64(&self, name: &str, inputs: &[&[f64]]) -> Result<Vec<Vec<f64>>> {
        let (reply, rx) = mpsc::channel();
        self.send(Command::Execute {
            name: name.to_string(),
            inputs: inputs.iter().map(|s| s.to_vec()).collect(),
            reply,
        });
        rx.recv().map_err(|_| anyhow::anyhow!("PJRT actor gone"))?
    }

    pub fn self_check(&self, name: &str) -> Result<()> {
        let (reply, rx) = mpsc::channel();
        self.send(Command::SelfCheck { name: name.to_string(), reply });
        rx.recv().map_err(|_| anyhow::anyhow!("PJRT actor gone"))?
    }

    /// Pre-compile a set of artifacts.
    pub fn warmup(&self, names: &[String]) -> Result<()> {
        let (reply, rx) = mpsc::channel();
        self.send(Command::Warmup { names: names.to_vec(), reply });
        rx.recv().map_err(|_| anyhow::anyhow!("PJRT actor gone"))?
    }

    pub fn platform(&self) -> Result<String> {
        let (reply, rx) = mpsc::channel();
        self.send(Command::Platform { reply });
        rx.recv().map_err(|_| anyhow::anyhow!("PJRT actor gone"))
    }

    /// Ask the actor to exit (outstanding commands are processed first).
    pub fn shutdown(&self) {
        self.send(Command::Shutdown);
    }
}
