//! PJRT executor: compile HLO-text artifacts once, execute from the hot
//! path with no Python anywhere.
//!
//! Follows the /opt/xla-example/load_hlo pattern: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. Compiled executables are cached by
//! artifact name; the coordinator shares one [`PjrtRuntime`] across
//! workers (the `xla` crate's client is internally synchronized).

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, ensure, Context, Result};

use super::manifest::{ArtifactSpec, Manifest};

/// A compiled artifact ready to execute.
pub struct Executable {
    spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
    /// Wall time spent compiling (exposed via metrics).
    pub compile_time_s: f64,
}

impl Executable {
    pub fn spec(&self) -> &ArtifactSpec {
        &self.spec
    }

    /// Execute with f64 host buffers, one per manifest input, in order.
    /// Returns one `Vec<f64>` per manifest output (scalars → length 1).
    pub fn run_f64(&self, inputs: &[&[f64]]) -> Result<Vec<Vec<f64>>> {
        ensure!(
            inputs.len() == self.spec.inputs.len(),
            "artifact {} expects {} inputs, got {}",
            self.spec.name,
            self.spec.inputs.len(),
            inputs.len()
        );
        let mut literals = Vec::with_capacity(inputs.len());
        for (buf, spec) in inputs.iter().zip(&self.spec.inputs) {
            ensure!(
                buf.len() == spec.element_count(),
                "input {:?} of {}: expected {} elements ({:?}), got {}",
                spec.name,
                self.spec.name,
                spec.element_count(),
                spec.shape,
                buf.len()
            );
            let lit = if spec.shape.is_empty() {
                xla::Literal::scalar(buf[0])
            } else {
                let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(buf).reshape(&dims)?
            };
            literals.push(lit);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        // aot.py lowers with return_tuple=True: the result is always a tuple.
        let parts = result.to_tuple()?;
        ensure!(
            parts.len() == self.spec.outputs.len(),
            "artifact {} returned {} outputs, manifest says {}",
            self.spec.name,
            parts.len(),
            self.spec.outputs.len()
        );
        let mut out = Vec::with_capacity(parts.len());
        for (lit, ospec) in parts.into_iter().zip(&self.spec.outputs) {
            let v = lit.to_vec::<f64>()?;
            ensure!(
                v.len() == ospec.element_count().max(1),
                "output {:?} of {}: expected {} elements, got {}",
                ospec.name,
                self.spec.name,
                ospec.element_count(),
                v.len()
            );
            out.push(v);
        }
        Ok(out)
    }

    /// Run the manifest's validation vector (deterministic excitations)
    /// and verify head + L2 agreement with what Python computed at build
    /// time. This is the cross-language correctness gate.
    pub fn self_check(&self) -> Result<()> {
        let val = self
            .spec
            .validation
            .as_ref()
            .ok_or_else(|| anyhow!("artifact {} has no validation block", self.spec.name))?;
        let dof = self.spec.inputs[0].element_count();
        let xi: Vec<f64> = (0..dof).map(|i| (0.37 * i as f64).sin()).collect();
        let out = &self.run_f64(&[&xi])?[0];
        for (i, (&got, &want)) in out.iter().zip(&val.out_head).enumerate() {
            ensure!(
                (got - want).abs() <= 1e-9 * (1.0 + want.abs()),
                "{}: self-check head[{i}] = {got} vs python {want}",
                self.spec.name
            );
        }
        let l2: f64 = out.iter().map(|v| v * v).sum::<f64>().sqrt();
        ensure!(
            (l2 - val.out_l2).abs() <= 1e-8 * (1.0 + val.out_l2),
            "{}: self-check L2 = {l2} vs python {}",
            self.spec.name,
            val.out_l2
        );
        Ok(())
    }
}

/// The shared PJRT runtime: one CPU client + a compile cache.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
}

impl PjrtRuntime {
    /// Create a CPU PJRT client and load the manifest from `artifact_dir`.
    pub fn new(artifact_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtRuntime { client, manifest, cache: Mutex::new(HashMap::new()) })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) an artifact by name.
    pub fn load(&self, name: &str) -> Result<Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.get(name)?.clone();
        let path = self.manifest.hlo_path(&spec);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {name}"))?;
        let compiled =
            Arc::new(Executable { spec, exe, compile_time_s: t0.elapsed().as_secs_f64() });
        self.cache.lock().unwrap().insert(name.to_string(), compiled.clone());
        Ok(compiled)
    }

    /// Convenience: load + execute in one call.
    pub fn execute_f64(&self, name: &str, inputs: &[&[f64]]) -> Result<Vec<Vec<f64>>> {
        self.load(name)?.run_f64(inputs)
    }

    /// Compile every artifact and run every validation vector; returns the
    /// list of checked names. `icr artifacts-check` exposes this.
    pub fn check_all(&self) -> Result<Vec<String>> {
        let names: Vec<String> = self.manifest.names().map(str::to_string).collect();
        let mut checked = Vec::new();
        for name in names {
            let exe = self.load(&name)?;
            if exe.spec().validation.is_some() {
                exe.self_check().with_context(|| format!("self-check of {name}"))?;
                checked.push(name);
            }
        }
        Ok(checked)
    }

    /// Number of executables currently cached.
    pub fn cached_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}
