//! Coordinate charts (paper §4.3).
//!
//! ICR refines on a *regular Euclidean grid*; a user-provided chart
//! `φ⁻¹` maps grid coordinates to the modeled domain 𝒟, and the kernel is
//! evaluated there: `k̃(ũ, ũ′) = k(φ⁻¹(ũ), φ⁻¹(ũ′))`. This module mirrors
//! `python/compile/charts.py` exactly — the Rust-native engine and the
//! JAX/Pallas artifacts must agree on geometry bit-for-bit (up to f64
//! round-off) for the native-vs-PJRT integration tests to pass.

/// A one-dimensional coordinate chart: a strictly monotone map from the
/// regular Euclidean refinement axis to the modeled domain.
pub trait Chart: Send + Sync {
    /// `φ⁻¹(u)`: Euclidean grid coordinate → domain location.
    fn to_domain(&self, u: f64) -> f64;

    /// `φ(x)`: domain location → Euclidean grid coordinate.
    fn to_grid(&self, x: f64) -> f64;

    /// Name for manifests/logs.
    fn name(&self) -> &'static str;

    /// Whether the chart is affine (`x = a + b·u`). Affine charts preserve
    /// the regular grid's translation invariance, so a stationary kernel
    /// needs only a *single* pair of refinement matrices per level
    /// (paper §4.3: broadcasting along invariant axes).
    fn is_affine(&self) -> bool {
        false
    }

    /// Distance *in the domain* between two grid coordinates. This is the
    /// only geometry the refinement-matrix construction consumes.
    fn domain_distance(&self, u0: f64, u1: f64) -> f64 {
        (self.to_domain(u0) - self.to_domain(u1)).abs()
    }
}

/// Identity (affine) chart: `x = offset + scale·u`. With `scale = Δ` this
/// is the plain regular grid of paper §4.2 / Algorithm 1.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct IdentityChart {
    pub offset: f64,
    pub scale: f64,
}

impl IdentityChart {
    pub fn new(offset: f64, scale: f64) -> Self {
        assert!(scale > 0.0, "chart scale must be positive");
        IdentityChart { offset, scale }
    }

    /// Unit regular grid.
    pub fn unit() -> Self {
        IdentityChart { offset: 0.0, scale: 1.0 }
    }
}

impl Chart for IdentityChart {
    fn to_domain(&self, u: f64) -> f64 {
        self.offset + self.scale * u
    }

    fn to_grid(&self, x: f64) -> f64 {
        (x - self.offset) / self.scale
    }

    fn name(&self) -> &'static str {
        "identity"
    }

    fn is_affine(&self) -> bool {
        true
    }

    fn domain_distance(&self, u0: f64, u1: f64) -> f64 {
        // Stationarity shortcut: distance depends only on |Δu|.
        self.scale * (u0 - u1).abs()
    }
}

/// Logarithmic chart `x = exp(α + β·u)` — the paper's §5 experiment
/// geometry ("logarithmically spaced points", Fig. 2b) and the spectral
/// axis of the detector example ("a logarithmic, spectral energy axis").
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LogChart {
    pub alpha: f64,
    pub beta: f64,
}

impl LogChart {
    pub fn new(alpha: f64, beta: f64) -> Self {
        assert!(beta != 0.0, "log chart slope must be nonzero");
        LogChart { alpha, beta }
    }

    /// Chart for the paper's §5.1 setup: `n` grid points with unit spacing
    /// whose *nearest-neighbour domain distances* sweep from `d_min` to
    /// `d_max` (the paper: 2 %·ρ₀ … ρ₀ over N ≈ 200 points).
    ///
    /// For `x_i = exp(α + β·i)` the neighbour gap is `x_i·(e^β − 1)`, so the
    /// gap ratio over the grid is `e^{β(n−2)}` and the smallest gap fixes α.
    pub fn from_neighbor_distances(n: usize, d_min: f64, d_max: f64) -> Self {
        assert!(n >= 3 && d_min > 0.0 && d_max > d_min);
        let beta = (d_max / d_min).ln() / (n as f64 - 2.0);
        let alpha = (d_min / (beta.exp() - 1.0)).ln();
        LogChart { alpha, beta }
    }
}

impl Chart for LogChart {
    fn to_domain(&self, u: f64) -> f64 {
        (self.alpha + self.beta * u).exp()
    }

    fn to_grid(&self, x: f64) -> f64 {
        assert!(x > 0.0, "log chart domain is (0, ∞)");
        (x.ln() - self.alpha) / self.beta
    }

    fn name(&self) -> &'static str {
        "log"
    }
}

/// Power-law chart `x = x₀·(1 + u/u₀)^γ` — a stand-in for radially
/// stretched astrophysical grids (the dust-map application [24] models a
/// GP on spherical coordinates with log-radius; a power-law radial chart
/// exercises the same non-uniform-stretch code path).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PowerChart {
    pub x0: f64,
    pub u0: f64,
    pub gamma: f64,
}

impl PowerChart {
    pub fn new(x0: f64, u0: f64, gamma: f64) -> Self {
        assert!(x0 > 0.0 && u0 > 0.0 && gamma > 0.0);
        PowerChart { x0, u0, gamma }
    }
}

impl Chart for PowerChart {
    fn to_domain(&self, u: f64) -> f64 {
        self.x0 * (1.0 + u / self.u0).powf(self.gamma)
    }

    fn to_grid(&self, x: f64) -> f64 {
        self.u0 * ((x / self.x0).powf(1.0 / self.gamma) - 1.0)
    }

    fn name(&self) -> &'static str {
        "power"
    }
}

/// Parse a chart spec string for the CLI/config:
/// `identity`, `identity(offset=0,scale=1)`, `log(alpha=0,beta=0.1)`,
/// `log_nn(n=200,dmin=0.02,dmax=1.0)`, `power(x0=1,u0=10,gamma=2)`.
pub fn parse_chart(spec: &str) -> Result<Box<dyn Chart>, String> {
    let spec = spec.trim();
    let (name, args) = match spec.find('(') {
        Some(i) => {
            let close = spec.rfind(')').ok_or_else(|| format!("unbalanced parens in chart spec {spec:?}"))?;
            (&spec[..i], &spec[i + 1..close])
        }
        None => (spec, ""),
    };
    let mut kv = std::collections::HashMap::new();
    for part in args.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        let (k, v) = part.split_once('=').ok_or_else(|| format!("bad chart arg {part:?}"))?;
        let val: f64 = v.trim().parse().map_err(|e| format!("bad chart value {v:?}: {e}"))?;
        kv.insert(k.trim().to_string(), val);
    }
    let get = |k: &str, dflt: f64| kv.get(k).copied().unwrap_or(dflt);
    match name {
        "identity" | "regular" => Ok(Box::new(IdentityChart::new(get("offset", 0.0), get("scale", 1.0)))),
        "log" => Ok(Box::new(LogChart::new(get("alpha", 0.0), get("beta", 0.1)))),
        "log_nn" => Ok(Box::new(LogChart::from_neighbor_distances(
            get("n", 200.0) as usize,
            get("dmin", 0.02),
            get("dmax", 1.0),
        ))),
        "power" => Ok(Box::new(PowerChart::new(get("x0", 1.0), get("u0", 10.0), get("gamma", 2.0)))),
        other => Err(format!("unknown chart {other:?}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_roundtrip(c: &dyn Chart, us: &[f64]) {
        for &u in us {
            let x = c.to_domain(u);
            let back = c.to_grid(x);
            assert!((back - u).abs() < 1e-9, "{}: roundtrip {u} -> {x} -> {back}", c.name());
        }
    }

    #[test]
    fn identity_roundtrip_and_distance() {
        let c = IdentityChart::new(3.0, 0.5);
        check_roundtrip(&c, &[-10.0, 0.0, 7.3, 1e4]);
        assert!((c.domain_distance(2.0, 6.0) - 2.0).abs() < 1e-15);
    }

    #[test]
    fn log_roundtrip_and_monotone() {
        let c = LogChart::new(-1.0, 0.05);
        check_roundtrip(&c, &[0.0, 1.0, 100.0, 250.0]);
        let mut prev = c.to_domain(0.0);
        for i in 1..100 {
            let v = c.to_domain(i as f64);
            assert!(v > prev);
            prev = v;
        }
    }

    #[test]
    fn log_chart_neighbor_distance_sweep() {
        // Paper §5.1: nn distances from 2%·ρ to ρ over ~200 points.
        let n = 200;
        let c = LogChart::from_neighbor_distances(n, 0.02, 1.0);
        let gaps: Vec<f64> =
            (0..n - 1).map(|i| c.to_domain(i as f64 + 1.0) - c.to_domain(i as f64)).collect();
        let dmin = gaps.iter().cloned().fold(f64::INFINITY, f64::min);
        let dmax = gaps.iter().cloned().fold(0.0_f64, f64::max);
        assert!((dmin - 0.02).abs() < 1e-10, "dmin {dmin}");
        assert!((dmax - 1.0).abs() < 1e-9, "dmax {dmax}");
        // Two orders of magnitude of spacing variation, as the abstract says.
        assert!(dmax / dmin > 49.0);
    }

    #[test]
    fn power_roundtrip() {
        let c = PowerChart::new(1.0, 16.0, 2.0);
        check_roundtrip(&c, &[0.0, 1.0, 31.0, 100.0]);
    }

    #[test]
    fn domain_distance_symmetric() {
        let charts: Vec<Box<dyn Chart>> = vec![
            Box::new(IdentityChart::unit()),
            Box::new(LogChart::new(0.0, 0.1)),
            Box::new(PowerChart::new(1.0, 8.0, 1.5)),
        ];
        for c in &charts {
            for &(a, b) in &[(0.0, 5.0), (2.0, 2.0), (10.0, 3.0)] {
                assert!((c.domain_distance(a, b) - c.domain_distance(b, a)).abs() < 1e-12);
                assert!(c.domain_distance(a, b) >= 0.0);
            }
        }
    }

    #[test]
    fn parse_chart_specs() {
        assert_eq!(parse_chart("identity").unwrap().name(), "identity");
        assert_eq!(parse_chart("log(alpha=0, beta=0.05)").unwrap().name(), "log");
        assert_eq!(parse_chart("log_nn(n=200, dmin=0.02, dmax=1.0)").unwrap().name(), "log");
        assert_eq!(parse_chart("power(x0=1, u0=8, gamma=2)").unwrap().name(), "power");
        assert!(parse_chart("bogus").is_err());
        assert!(parse_chart("log(alpha=x)").is_err());
    }
}
