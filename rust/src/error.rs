//! Typed error surface of the serving stack (protocol v2).
//!
//! The request path used to funnel every failure through stringly
//! `anyhow::Error`; v2 of the JSONL protocol reports machine-readable
//! error frames instead, so the coordinator and the wire codec share this
//! enum. Each variant maps to a stable `kind` string on the wire
//! (`{"error": {"kind": "...", "message": "..."}}`).

use std::fmt;

/// Errors produced on the coordinator request path and encoded into
/// protocol-v2 error frames.
#[derive(Debug, Clone, PartialEq)]
pub enum IcrError {
    /// Request named a model the registry does not host.
    UnknownModel { name: String, available: Vec<String> },
    /// Request `op` is not part of the protocol.
    UnknownOp(String),
    /// Frame was not valid JSON / missing required fields.
    MalformedRequest(String),
    /// Frame declared a protocol version the server does not speak.
    UnsupportedProtocol(u64),
    /// A vector argument had the wrong length.
    ShapeMismatch { what: &'static str, expected: usize, got: usize },
    /// A scalar argument was out of range (σ ≤ 0, steps = 0, …).
    InvalidParameter(String),
    /// The model cannot serve this op (e.g. no loss-grad artifact).
    Unsupported(String),
    /// The server is saturated (bounded request queue full, or the
    /// connection cap reached); the client should back off and retry.
    Overloaded { in_use: usize, limit: usize },
    /// The backing engine failed executing the request.
    Backend(String),
    /// A model artifact on disk is structurally unreadable: missing or
    /// malformed manifest, truncated payload, inconsistent geometry.
    ArtifactCorrupt(String),
    /// A content digest did not match its declared value — an artifact
    /// payload SHA-256, a config checksum, or a remote shard whose
    /// `describe` identity mismatches the declared spec.
    ChecksumMismatch { what: String, expected: String, got: String },
    /// Coordinator-internal failure (dropped reply channel, poisoned lock).
    Internal(String),
    /// A routed request failed retryably, and the failover machinery
    /// ran out of attempts or deadline budget before any member
    /// answered (`DESIGN.md` §12). Carries the attempt count, the
    /// configured budget, and the last member failure.
    RetryExhausted { attempts: usize, budget_ms: u64, last: String },
}

impl IcrError {
    /// Stable wire identifier for the error class.
    pub fn kind(&self) -> &'static str {
        match self {
            IcrError::UnknownModel { .. } => "unknown_model",
            IcrError::UnknownOp(_) => "unknown_op",
            IcrError::MalformedRequest(_) => "malformed_request",
            IcrError::UnsupportedProtocol(_) => "unsupported_protocol",
            IcrError::ShapeMismatch { .. } => "shape_mismatch",
            IcrError::InvalidParameter(_) => "invalid_parameter",
            IcrError::Unsupported(_) => "unsupported",
            IcrError::Overloaded { .. } => "overloaded",
            IcrError::Backend(_) => "backend",
            IcrError::ArtifactCorrupt(_) => "artifact_corrupt",
            IcrError::ChecksumMismatch { .. } => "checksum_mismatch",
            IcrError::Internal(_) => "internal",
            IcrError::RetryExhausted { .. } => "retry_exhausted",
        }
    }

    /// Whether this failure says something about the *member's* health
    /// (connect refused, call timeout, remote/internal failure) rather
    /// than about the request itself — the classification shared by
    /// circuit-breaker accounting and retry/failover gating
    /// (`DESIGN.md` §12). Client errors (bad shapes, unknown ops,
    /// unsupported params) are the caller's fault on any member and
    /// are neither counted against breakers nor retried.
    pub fn is_member_fault(&self) -> bool {
        matches!(self, IcrError::Backend(_) | IcrError::Internal(_))
    }

    /// Wrap an engine/backend failure, keeping the full anyhow chain.
    pub fn backend(e: impl fmt::Display) -> Self {
        IcrError::Backend(format!("{e}"))
    }

    /// Reconstruct from a decoded wire frame. Unknown kinds degrade to
    /// [`IcrError::Internal`] so old clients survive new server kinds.
    pub fn from_wire(kind: &str, message: &str) -> Self {
        match kind {
            "unknown_model" => {
                IcrError::UnknownModel { name: message.to_string(), available: Vec::new() }
            }
            "unknown_op" => IcrError::UnknownOp(message.to_string()),
            "malformed_request" => IcrError::MalformedRequest(message.to_string()),
            "unsupported_protocol" => {
                IcrError::UnsupportedProtocol(message.parse().unwrap_or(0))
            }
            "shape_mismatch" => {
                IcrError::ShapeMismatch { what: "wire", expected: 0, got: 0 }
            }
            "invalid_parameter" => IcrError::InvalidParameter(message.to_string()),
            "unsupported" => IcrError::Unsupported(message.to_string()),
            "overloaded" => IcrError::Overloaded { in_use: 0, limit: 0 },
            "backend" => IcrError::Backend(message.to_string()),
            "artifact_corrupt" => IcrError::ArtifactCorrupt(message.to_string()),
            "checksum_mismatch" => IcrError::ChecksumMismatch {
                what: message.to_string(),
                expected: String::new(),
                got: String::new(),
            },
            "retry_exhausted" => IcrError::RetryExhausted {
                attempts: 0,
                budget_ms: 0,
                last: message.to_string(),
            },
            _ => IcrError::Internal(message.to_string()),
        }
    }
}

impl fmt::Display for IcrError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            IcrError::UnknownModel { name, available } => {
                write!(f, "unknown model {name:?} (available: {})", available.join(", "))
            }
            IcrError::UnknownOp(op) => write!(f, "unknown op {op:?}"),
            IcrError::MalformedRequest(m) => write!(f, "malformed request: {m}"),
            IcrError::UnsupportedProtocol(v) => {
                write!(f, "unsupported protocol version {v} (supported: 1, 2)")
            }
            IcrError::ShapeMismatch { what, expected, got } => {
                write!(f, "{what} length mismatch: expected {expected}, got {got}")
            }
            IcrError::InvalidParameter(m) => write!(f, "invalid parameter: {m}"),
            IcrError::Unsupported(m) => write!(f, "unsupported operation: {m}"),
            IcrError::Overloaded { in_use, limit } => {
                write!(f, "server overloaded: {in_use} of {limit} slots in use, retry later")
            }
            IcrError::Backend(m) => write!(f, "backend failure: {m}"),
            IcrError::ArtifactCorrupt(m) => write!(f, "artifact corrupt: {m}"),
            IcrError::ChecksumMismatch { what, expected, got } => {
                write!(f, "{what} checksum mismatch: expected {expected}, got {got}")
            }
            IcrError::Internal(m) => write!(f, "internal error: {m}"),
            IcrError::RetryExhausted { attempts, budget_ms, last } => write!(
                f,
                "retry budget exhausted after {attempts} attempt(s) within {budget_ms} ms; \
                 last failure: {last}"
            ),
        }
    }
}

impl std::error::Error for IcrError {}

impl From<anyhow::Error> for IcrError {
    fn from(e: anyhow::Error) -> Self {
        IcrError::Backend(format!("{e:#}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kinds_are_stable_and_distinct() {
        let errs = [
            IcrError::UnknownModel { name: "x".into(), available: vec![] },
            IcrError::UnknownOp("x".into()),
            IcrError::MalformedRequest("x".into()),
            IcrError::UnsupportedProtocol(3),
            IcrError::ShapeMismatch { what: "xi", expected: 1, got: 2 },
            IcrError::InvalidParameter("x".into()),
            IcrError::Unsupported("x".into()),
            IcrError::Overloaded { in_use: 8, limit: 8 },
            IcrError::Backend("x".into()),
            IcrError::ArtifactCorrupt("x".into()),
            IcrError::ChecksumMismatch {
                what: "payload".into(),
                expected: "aa".into(),
                got: "bb".into(),
            },
            IcrError::Internal("x".into()),
            IcrError::RetryExhausted { attempts: 3, budget_ms: 100, last: "x".into() },
        ];
        let kinds: std::collections::BTreeSet<&str> = errs.iter().map(|e| e.kind()).collect();
        assert_eq!(kinds.len(), errs.len());
        for e in &errs {
            // Every kind survives a wire round-trip onto the same kind.
            assert_eq!(IcrError::from_wire(e.kind(), "m").kind(), e.kind());
        }
    }

    #[test]
    fn display_names_the_problem() {
        let e = IcrError::UnknownModel { name: "kiss".into(), available: vec!["default".into()] };
        let msg = e.to_string();
        assert!(msg.contains("kiss") && msg.contains("default"), "{msg}");
    }

    #[test]
    fn anyhow_interop_both_directions() {
        let ic: IcrError = anyhow::anyhow!("boom").into();
        assert_eq!(ic.kind(), "backend");
        let back: anyhow::Error = IcrError::UnknownOp("z".into()).into();
        assert!(back.to_string().contains("unknown op"));
    }
}
