//! Transport layer: socket listeners, connection hosting, graceful drain.
//!
//! [`NetServer`] owns a TCP or Unix-domain listener and hosts accepted
//! connections in one of two io modes ([`super::IoMode`]): the default
//! event-driven readiness loop ([`super::event_loop`], `DESIGN.md` §11)
//! where a single thread owns every socket, or the legacy
//! thread-per-session accept loop here (`--io-mode threads`) spawning
//! one [`super::session`] per connection. Both paths poll a shutdown
//! flag (set programmatically through [`NetServer::shutdown_handle`] or
//! by the SIGINT handler installed via [`install_sigint_handler`]); once
//! draining, no new connections are accepted, every live connection
//! finishes flushing its in-flight replies, and `run` returns.
//! Connections beyond `--max-connections` are refused with a typed
//! `overloaded` error frame before close.

use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::io::{AsRawFd, RawFd};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::config::ServerConfig;
use crate::coordinator::{protocol, Coordinator};
use crate::error::IcrError;

use super::session::{self, SessionCtx};
use super::{IoMode, ListenAddr};

/// How often the accept loop re-checks the shutdown flag when idle.
const ACCEPT_POLL: Duration = Duration::from_millis(10);

static SIGINT_HIT: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
extern "C" fn on_sigint(_sig: i32) {
    // A store on an AtomicBool is async-signal-safe; everything else
    // happens on the accept/session threads that poll the flag.
    SIGINT_HIT.store(true, Ordering::SeqCst);
}

/// Install a process-wide SIGINT handler that requests a graceful drain:
/// the accept loop stops taking connections, in-flight requests are
/// answered, then `run` returns. Only the serving binary installs this;
/// tests drive the programmatic [`NetServer::shutdown_handle`] instead.
#[cfg(unix)]
pub fn install_sigint_handler() {
    // Declared locally so the crate needs no libc dependency; the libc
    // prototype is `sighandler_t signal(int, sighandler_t)` with
    // `sighandler_t = void (*)(int)`, ABI-identical to this declaration.
    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }
    const SIGINT: i32 = 2;
    unsafe {
        signal(SIGINT, on_sigint);
    }
}

#[cfg(not(unix))]
pub fn install_sigint_handler() {}

/// Whether SIGINT requested a drain.
pub fn sigint_requested() -> bool {
    SIGINT_HIT.load(Ordering::SeqCst)
}

/// The two socket listener families behind one accept surface.
pub(crate) enum Listener {
    Tcp(TcpListener),
    #[cfg(unix)]
    Unix(UnixListener),
}

impl Listener {
    fn set_nonblocking(&self, on: bool) -> io::Result<()> {
        match self {
            Listener::Tcp(l) => l.set_nonblocking(on),
            #[cfg(unix)]
            Listener::Unix(l) => l.set_nonblocking(on),
        }
    }

    /// Accept one connection. Accepted sockets inherit the listener's
    /// non-blocking flag on some platforms and not others, so the mode
    /// the host needs is set explicitly: sessions block on reads
    /// (`blocking`), the event loop never blocks (`!blocking`).
    pub(crate) fn accept(&self, blocking: bool) -> io::Result<Conn> {
        match self {
            Listener::Tcp(l) => {
                let (s, _) = l.accept()?;
                s.set_nonblocking(!blocking)?;
                s.set_nodelay(true).ok();
                Ok(Conn::Tcp(s))
            }
            #[cfg(unix)]
            Listener::Unix(l) => {
                let (s, _) = l.accept()?;
                s.set_nonblocking(!blocking)?;
                Ok(Conn::Unix(s))
            }
        }
    }
}

#[cfg(unix)]
impl AsRawFd for Listener {
    fn as_raw_fd(&self) -> RawFd {
        match self {
            Listener::Tcp(l) => l.as_raw_fd(),
            Listener::Unix(l) => l.as_raw_fd(),
        }
    }
}

/// One accepted client connection (either family), readable and
/// writable; the session clones it into a read half and a write half.
pub(crate) enum Conn {
    Tcp(TcpStream),
    #[cfg(unix)]
    Unix(UnixStream),
}

impl Conn {
    pub(crate) fn try_clone(&self) -> io::Result<Conn> {
        match self {
            Conn::Tcp(s) => s.try_clone().map(Conn::Tcp),
            #[cfg(unix)]
            Conn::Unix(s) => s.try_clone().map(Conn::Unix),
        }
    }

    pub(crate) fn set_read_timeout(&self, d: Option<Duration>) -> io::Result<()> {
        match self {
            Conn::Tcp(s) => s.set_read_timeout(d),
            #[cfg(unix)]
            Conn::Unix(s) => s.set_read_timeout(d),
        }
    }
}

#[cfg(unix)]
impl AsRawFd for Conn {
    fn as_raw_fd(&self) -> RawFd {
        match self {
            Conn::Tcp(s) => s.as_raw_fd(),
            Conn::Unix(s) => s.as_raw_fd(),
        }
    }
}

impl Read for Conn {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            Conn::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Conn {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            Conn::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        match self {
            Conn::Tcp(s) => s.flush(),
            #[cfg(unix)]
            Conn::Unix(s) => s.flush(),
        }
    }
}

/// A bound, not-yet-running server: the socket exists after
/// [`NetServer::bind`] (so clients can connect as soon as [`NetServer::run`]
/// starts accepting), and `run` blocks until a drain completes.
pub struct NetServer {
    pub(crate) listener: Listener,
    pub(crate) coord: Arc<Coordinator>,
    pub(crate) max_connections: usize,
    pub(crate) idle_timeout: Duration,
    pub(crate) shutdown: Arc<AtomicBool>,
    local: String,
    pub(crate) unix_path: Option<PathBuf>,
    io_mode: IoMode,
    /// Threads-mode reader poll granularity (`--io-poll-ms`).
    io_poll: Duration,
    /// Bound `--metrics-listen` scrape socket (DESIGN.md §13), if
    /// configured. The event loop hosts it on its own poller; threads
    /// mode hands it to the blocking [`crate::obs::spawn_metrics_listener`].
    pub(crate) metrics_listener: Option<TcpListener>,
    metrics_local: Option<String>,
}

impl NetServer {
    /// Bind the configured listen address. `ListenAddr::Stdio` is served
    /// by the inline loop in `main.rs`, not by a socket server.
    pub fn bind(cfg: &ServerConfig, coord: Arc<Coordinator>) -> Result<NetServer> {
        let (listener, local, unix_path) = match &cfg.listen {
            ListenAddr::Stdio => {
                anyhow::bail!("--listen stdio is served inline, not by the socket server")
            }
            ListenAddr::Tcp(hp) => {
                let l = TcpListener::bind(hp).with_context(|| format!("binding tcp:{hp}"))?;
                let local = l
                    .local_addr()
                    .map(|a| format!("tcp:{a}"))
                    .unwrap_or_else(|_| format!("tcp:{hp}"));
                (Listener::Tcp(l), local, None)
            }
            #[cfg(unix)]
            ListenAddr::Unix(path) => {
                // A socket file left by a dead server would fail the
                // bind, but a live server still answers on it — probe
                // before removing so binding never hijacks a running
                // instance's address.
                if path.exists() {
                    anyhow::ensure!(
                        UnixStream::connect(path).is_err(),
                        "unix:{} is in use by a live server",
                        path.display()
                    );
                    std::fs::remove_file(path).ok();
                }
                let l = UnixListener::bind(path)
                    .with_context(|| format!("binding unix:{}", path.display()))?;
                (Listener::Unix(l), format!("unix:{}", path.display()), Some(path.clone()))
            }
            #[cfg(not(unix))]
            ListenAddr::Unix(path) => {
                anyhow::bail!("unix sockets are not supported on this platform: {}", path.display())
            }
        };
        listener.set_nonblocking(true).context("non-blocking listener")?;
        let (metrics_listener, metrics_local) = bind_metrics(cfg)?;
        Ok(NetServer {
            listener,
            coord,
            max_connections: cfg.max_connections.max(1),
            idle_timeout: Duration::from_millis(cfg.idle_timeout_ms),
            shutdown: Arc::new(AtomicBool::new(false)),
            local,
            unix_path,
            io_mode: cfg.io_mode,
            io_poll: Duration::from_millis(cfg.io_poll_ms.max(1)),
            metrics_listener,
            metrics_local,
        })
    }

    /// The bound address (`tcp:IP:PORT` with the resolved ephemeral port,
    /// or `unix:PATH`).
    pub fn local_addr(&self) -> &str {
        &self.local
    }

    /// The bound `--metrics-listen` address (`tcp:IP:PORT` with the
    /// resolved ephemeral port), if a scrape endpoint is configured.
    pub fn metrics_addr(&self) -> Option<&str> {
        self.metrics_local.as_deref()
    }

    /// Flag requesting a graceful drain; sharable with signal handlers,
    /// watchdogs and tests.
    pub fn shutdown_handle(&self) -> Arc<AtomicBool> {
        self.shutdown.clone()
    }

    fn draining(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst) || sigint_requested()
    }

    /// Host connections until a drain is requested (handle or SIGINT)
    /// and every connection has flushed its in-flight replies; then
    /// return. The coordinator is left running — the caller owns its
    /// shutdown. Dispatches on `--io-mode`: the event-driven readiness
    /// loop (default) or the legacy thread-per-session accept loop.
    pub fn run(self) -> Result<()> {
        #[cfg(unix)]
        if self.io_mode == IoMode::Event {
            return super::event_loop::run(self);
        }
        self.run_threads()
    }

    /// The legacy accept loop: two threads per connection.
    fn run_threads(mut self) -> Result<()> {
        let transport = self.coord.transport_metrics().clone();
        // Threads mode has no readiness loop to host the scrape
        // endpoint on; hand the bound socket to the blocking accept
        // thread instead (identical exposition document either way).
        // The scrape thread gets its own stop flag, NOT the server's
        // drain flag: scrapes must keep answering through the whole
        // drain window (`DESIGN.md` §14) so an operator can watch
        // in-flight work flush; it stops only after every session
        // joined.
        let metrics_stop = Arc::new(AtomicBool::new(false));
        let metrics_thread = match self.metrics_listener.take() {
            Some(l) => {
                let coord = self.coord.clone();
                Some(
                    crate::obs::spawn_metrics_listener(
                        l,
                        metrics_stop.clone(),
                        Arc::new(move || coord.render_prometheus()),
                    )
                    .context("spawning metrics listener")?,
                )
            }
            None => None,
        };
        let open = Arc::new(AtomicUsize::new(0));
        let mut sessions: Vec<std::thread::JoinHandle<()>> = Vec::new();
        let mut next_sid = 0u64;
        while !self.draining() {
            // Reap every iteration, not just when idle — sustained
            // connection churn must not grow the handle list unboundedly.
            sessions.retain(|h| !h.is_finished());
            match self.listener.accept(true) {
                Ok(conn) => {
                    transport.counter("connections_total").inc();
                    if open.load(Ordering::SeqCst) >= self.max_connections {
                        transport.counter("connections_rejected").inc();
                        refuse(conn, open.load(Ordering::SeqCst), self.max_connections);
                        continue;
                    }
                    open.fetch_add(1, Ordering::SeqCst);
                    transport.gauge("connections_open").inc();
                    next_sid += 1;
                    let ctx = SessionCtx {
                        coord: self.coord.clone(),
                        shutdown: self.shutdown.clone(),
                        idle_timeout: self.idle_timeout,
                        io_poll: self.io_poll,
                        transport: transport.clone(),
                        open: open.clone(),
                    };
                    let handle = std::thread::Builder::new()
                        .name(format!("icr-session-{next_sid}"))
                        .spawn(move || session::run(conn, ctx))
                        .context("spawning session thread")?;
                    sessions.push(handle);
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    std::thread::sleep(ACCEPT_POLL);
                }
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e).context("accepting connection"),
            }
        }
        // Drain: new connections are no longer accepted; sessions stop
        // reading frames and flush replies to everything already
        // submitted, then hang up.
        for h in sessions {
            let _ = h.join();
        }
        if let Some(h) = metrics_thread {
            // Every session has flushed — only now stop the scrape
            // thread, so metrics stayed observable for the entire drain.
            metrics_stop.store(true, Ordering::SeqCst);
            let _ = h.join();
        }
        if let Some(path) = &self.unix_path {
            std::fs::remove_file(path).ok();
        }
        Ok(())
    }
}

/// Bind the configured `--metrics-listen` scrape socket, returning the
/// listener plus its resolved `tcp:IP:PORT` address. Shared by the
/// socket server and the stdio serving loop in `main.rs`.
pub fn bind_metrics(
    cfg: &ServerConfig,
) -> Result<(Option<TcpListener>, Option<String>)> {
    let Some(spec) = &cfg.metrics_listen else { return Ok((None, None)) };
    let hp = match super::ListenAddr::parse(spec) {
        Ok(super::ListenAddr::Tcp(hp)) => hp,
        // The config layer already rejected non-TCP specs at startup.
        _ => anyhow::bail!("--metrics-listen must be tcp:HOST:PORT, got {spec:?}"),
    };
    let l = TcpListener::bind(&hp).with_context(|| format!("binding metrics tcp:{hp}"))?;
    l.set_nonblocking(true).context("non-blocking metrics listener")?;
    let local = l.local_addr().map(|a| format!("tcp:{a}")).unwrap_or_else(|_| format!("tcp:{hp}"));
    Ok((Some(l), Some(local)))
}

/// Answer an over-cap connection with one typed `overloaded` frame and
/// hang up. Best-effort on a non-blocking socket: the ~120-byte frame
/// fits any fresh socket send buffer, and a peer that already vanished
/// simply misses its refusal.
pub(crate) fn refuse(mut conn: Conn, in_use: usize, limit: usize) {
    let err = IcrError::Overloaded { in_use, limit };
    let frame = protocol::encode_response(protocol::PROTOCOL_VERSION, 0, None, &Err(err), None);
    let _ = writeln!(conn, "{}", frame.to_json());
    let _ = conn.flush();
}
