//! Shard/replica router over the model registry.
//!
//! `DESIGN.md` §5 reserved the hook: a shard is a named
//! [`crate::model::GpModel`] registry entry. A **replica set** groups N
//! member entries (`--replicas gp=native:3` → members `gp@0..gp@2`;
//! mixed local+remote sets add `remote:tcp:HOST:PORT` members) under one
//! logical name; requests addressed to the logical name are routed to a
//! member by a pluggable [`RoutePolicy`]. Requests may still address a
//! member (`gp@1`) directly — the router only resolves names the
//! registry does not already host.
//!
//! **Member health** (`DESIGN.md` §9): every member carries a
//! [`MemberState`]. Only `Healthy` members receive newly routed traffic;
//! `Draining` members finish their in-flight work but are skipped by
//! selection, and `Ejected` members failed their health probe and are
//! skipped until a probe succeeds again. If no member is available the
//! router falls back to the full set (availability over purity — a
//! wholly ejected set keeps answering rather than blackholing).
//!
//! **Circuit breakers** (`DESIGN.md` §12): orthogonally to probe-driven
//! health, every member carries a request-level breaker fed by
//! [`Router::record_outcome`] — sliding-window failure accounting with a
//! Closed → Open → Half-Open state machine, so a member that answers
//! probes but errors or times out on real requests stops receiving
//! traffic (typed `member_tripped` reason in stats) until bounded
//! Half-Open trials prove it recovered. A tripped member's seeds remap
//! under rendezvous hashing exactly like an ejected one's.
//!
//! Determinism: every member of a set serves the same model, so `sample`
//! bytes are identical regardless of the policy's choice; `seed_affinity`
//! additionally pins a given seed to a fixed member via **rendezvous
//! (highest-random-weight) hashing** — each seed independently ranks all
//! members, so ejecting a member only moves the seeds it owned and
//! adding one only claims the seeds it now wins; assignments of
//! unrelated seeds never change (property-tested below and in
//! `cluster_e2e.rs`).

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::coordinator::request::Request;
use crate::json::{self, Value};

/// How a replica set picks the member serving the next request.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RoutePolicy {
    /// Strict rotation over the available members.
    RoundRobin,
    /// Available member with the fewest requests currently in flight
    /// (ties resolve to the lowest member index).
    LeastOutstanding,
    /// Seeded requests (`sample`, `infer_multi`) pick the member with
    /// the highest rendezvous weight for the seed, so a given seed
    /// always lands on the same member while it stays available;
    /// unseeded requests fall back to rotation.
    #[default]
    SeedAffinity,
}

impl RoutePolicy {
    /// Every policy, in the order advertised by `icr --version` and the
    /// `stats` document.
    pub const ALL: [RoutePolicy; 3] =
        [RoutePolicy::RoundRobin, RoutePolicy::LeastOutstanding, RoutePolicy::SeedAffinity];

    pub fn parse(s: &str) -> Result<RoutePolicy, String> {
        match s {
            "round_robin" | "rr" => Ok(RoutePolicy::RoundRobin),
            "least_outstanding" | "lo" => Ok(RoutePolicy::LeastOutstanding),
            "seed_affinity" | "seed" => Ok(RoutePolicy::SeedAffinity),
            other => Err(format!(
                "unknown routing policy {other:?} (round_robin|least_outstanding|seed_affinity)"
            )),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            RoutePolicy::RoundRobin => "round_robin",
            RoutePolicy::LeastOutstanding => "least_outstanding",
            RoutePolicy::SeedAffinity => "seed_affinity",
        }
    }
}

/// Lifecycle of a replica-set member, driven by the coordinator's health
/// monitor and by graceful drains.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MemberState {
    /// Eligible for new traffic.
    Healthy,
    /// Finishing in-flight work; selection skips it (satellite fix: a
    /// draining member used to keep receiving `least_outstanding`
    /// traffic until its session closed).
    Draining,
    /// Failed its health probe; skipped until a probe succeeds again.
    Ejected,
}

impl MemberState {
    fn as_u8(self) -> u8 {
        match self {
            MemberState::Healthy => 0,
            MemberState::Draining => 1,
            MemberState::Ejected => 2,
        }
    }

    fn from_u8(b: u8) -> MemberState {
        match b {
            1 => MemberState::Draining,
            2 => MemberState::Ejected,
            _ => MemberState::Healthy,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            MemberState::Healthy => "healthy",
            MemberState::Draining => "draining",
            MemberState::Ejected => "ejected",
        }
    }
}

/// Request-level circuit-breaker tuning, shared by every member
/// (`DESIGN.md` §12). Health probes catch dead processes; the breaker
/// catches members that answer probes but fail *requests*.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BreakerConfig {
    /// Sliding window of recent request outcomes per member. A member
    /// trips only once the window is full, so a single early failure
    /// cannot open the circuit. `0` disables breakers entirely.
    pub window: usize,
    /// Failure ratio within a full window that trips Closed → Open.
    pub trip_ratio: f64,
    /// How long a tripped member stays Open before Half-Open trials.
    pub cooldown: Duration,
    /// Bounded trial requests admitted while Half-Open.
    pub trials: usize,
}

impl Default for BreakerConfig {
    fn default() -> BreakerConfig {
        BreakerConfig {
            window: 16,
            trip_ratio: 0.5,
            cooldown: Duration::from_millis(1000),
            trials: 2,
        }
    }
}

/// Circuit-breaker state of one member. Composes with [`MemberState`]:
/// a member receives new traffic only when Healthy *and* its breaker
/// admits it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerState {
    /// Normal operation; outcomes accumulate in the sliding window.
    Closed,
    /// Tripped: no new traffic until the cooldown elapses.
    Open,
    /// Cooldown elapsed: a bounded number of trial requests probe the
    /// member; one success re-closes, one failure re-opens.
    HalfOpen,
}

impl BreakerState {
    pub fn name(self) -> &'static str {
        match self {
            BreakerState::Closed => "closed",
            BreakerState::Open => "open",
            BreakerState::HalfOpen => "half_open",
        }
    }
}

/// Per-member sliding-window failure accounting (`true` = failure).
struct Breaker {
    outcomes: VecDeque<bool>,
    failures: usize,
    state: BreakerState,
    opened_at: Option<Instant>,
    trials_issued: usize,
    trips: u64,
}

impl Breaker {
    fn new() -> Breaker {
        Breaker {
            outcomes: VecDeque::new(),
            failures: 0,
            state: BreakerState::Closed,
            opened_at: None,
            trials_issued: 0,
            trips: 0,
        }
    }

    fn trip(&mut self) {
        self.state = BreakerState::Open;
        self.opened_at = Some(Instant::now());
        self.trips += 1;
        self.outcomes.clear();
        self.failures = 0;
        self.trials_issued = 0;
    }

    fn reset(&mut self) {
        self.state = BreakerState::Closed;
        self.opened_at = None;
        self.outcomes.clear();
        self.failures = 0;
        self.trials_issued = 0;
    }

    /// Whether the member may receive new traffic right now. Lazily
    /// advances Open → Half-Open once the cooldown has elapsed.
    fn admits(&mut self, cfg: &BreakerConfig) -> bool {
        match self.state {
            BreakerState::Closed => true,
            BreakerState::Open => {
                let elapsed = self.opened_at.map(|t| t.elapsed()).unwrap_or(cfg.cooldown);
                if elapsed >= cfg.cooldown {
                    self.state = BreakerState::HalfOpen;
                    self.trials_issued = 0;
                    cfg.trials > 0
                } else {
                    false
                }
            }
            BreakerState::HalfOpen => self.trials_issued < cfg.trials,
        }
    }

    /// Called when the member is actually selected for a request.
    fn note_routed(&mut self) {
        if self.state == BreakerState::HalfOpen {
            self.trials_issued += 1;
        }
    }

    /// Record one request outcome for this member.
    fn record(&mut self, cfg: &BreakerConfig, ok: bool) {
        match self.state {
            BreakerState::Closed => {
                if self.outcomes.len() == cfg.window {
                    if self.outcomes.pop_front() == Some(true) {
                        self.failures -= 1;
                    }
                }
                self.outcomes.push_back(!ok);
                if !ok {
                    self.failures += 1;
                }
                let full = self.outcomes.len() >= cfg.window;
                if full && self.failures as f64 >= cfg.trip_ratio * cfg.window as f64 {
                    self.trip();
                }
            }
            BreakerState::HalfOpen => {
                if ok {
                    self.reset();
                } else {
                    self.trip();
                }
            }
            // Straggler outcomes from requests issued before the trip:
            // the window restarts from scratch at Half-Open.
            BreakerState::Open => {}
        }
    }
}

/// One logical replica set: ordered member entry names plus routing
/// state (rotation cursor, per-member routed counters, member states,
/// circuit breakers).
pub struct ReplicaSet {
    members: Vec<String>,
    rr: AtomicUsize,
    routed: Vec<AtomicU64>,
    state: Vec<AtomicU8>,
    breaker: Vec<Mutex<Breaker>>,
}

impl ReplicaSet {
    fn new(members: Vec<String>) -> ReplicaSet {
        let routed = members.iter().map(|_| AtomicU64::new(0)).collect();
        let state = members.iter().map(|_| AtomicU8::new(0)).collect();
        let breaker = members.iter().map(|_| Mutex::new(Breaker::new())).collect();
        ReplicaSet { members, rr: AtomicUsize::new(0), routed, state, breaker }
    }

    pub fn members(&self) -> &[String] {
        &self.members
    }

    /// How many requests this set has routed to member `i`.
    pub fn routed_to(&self, i: usize) -> u64 {
        self.routed[i].load(Ordering::Relaxed)
    }

    pub fn member_state(&self, i: usize) -> MemberState {
        MemberState::from_u8(self.state[i].load(Ordering::SeqCst))
    }

    fn set_state(&self, i: usize, s: MemberState) {
        self.state[i].store(s.as_u8(), Ordering::SeqCst);
    }

    /// This member's breaker state (read-only; does not advance
    /// Open → Half-Open).
    pub fn breaker_state(&self, i: usize) -> BreakerState {
        self.breaker[i].lock().unwrap().state
    }

    /// How many times this member's breaker has tripped to Open.
    pub fn breaker_trips(&self, i: usize) -> u64 {
        self.breaker[i].lock().unwrap().trips
    }

    /// Indices of members eligible for new traffic: Healthy *and*
    /// admitted by their circuit breaker. Availability over purity, in
    /// two stages: if every healthy member is tripped, breakers are
    /// ignored (a wholly tripped set keeps answering); if no member is
    /// healthy at all, the full set is used.
    fn available(&self, cfg: &BreakerConfig) -> Vec<usize> {
        let healthy: Vec<usize> = (0..self.members.len())
            .filter(|&i| self.member_state(i) == MemberState::Healthy)
            .collect();
        if healthy.is_empty() {
            return (0..self.members.len()).collect();
        }
        if cfg.window == 0 {
            return healthy;
        }
        let admitted: Vec<usize> = healthy
            .iter()
            .copied()
            .filter(|&i| self.breaker[i].lock().unwrap().admits(cfg))
            .collect();
        if admitted.is_empty() {
            healthy
        } else {
            admitted
        }
    }
}

/// The seed a request pins replica affinity on, when it has one.
fn affinity_seed(request: &Request) -> Option<u64> {
    match request {
        Request::Sample { seed, .. } => Some(*seed),
        Request::InferMulti { seed, .. } => Some(*seed),
        _ => None,
    }
}

/// Deterministic rendezvous (highest-random-weight) score: FNV-1a over
/// the member name, mixed with the seed through a splitmix64 finalizer.
/// Each (seed, member) pair scores independently, which is what makes
/// assignments of unrelated seeds immune to membership changes.
fn rendezvous_weight(seed: u64, member: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in member.as_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    let mut z = h ^ seed.wrapping_mul(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Maps logical replica-set names to member registry entries.
pub struct Router {
    policy: RoutePolicy,
    breaker_cfg: BreakerConfig,
    sets: BTreeMap<String, ReplicaSet>,
}

impl Router {
    pub fn new(policy: RoutePolicy) -> Router {
        Router { policy, breaker_cfg: BreakerConfig::default(), sets: BTreeMap::new() }
    }

    /// Replace the circuit-breaker tuning (before serving starts).
    pub fn set_breaker_config(&mut self, cfg: BreakerConfig) {
        self.breaker_cfg = cfg;
    }

    pub fn breaker_config(&self) -> &BreakerConfig {
        &self.breaker_cfg
    }

    /// Register a logical name over its (non-empty, ordered) members.
    pub fn add_set(&mut self, logical: &str, members: Vec<String>) {
        debug_assert!(!members.is_empty(), "replica set {logical:?} has no members");
        self.sets.insert(logical.to_string(), ReplicaSet::new(members));
    }

    pub fn is_empty(&self) -> bool {
        self.sets.is_empty()
    }

    pub fn policy(&self) -> RoutePolicy {
        self.policy
    }

    /// Logical names this router resolves (sorted).
    pub fn logical_names(&self) -> Vec<String> {
        self.sets.keys().cloned().collect()
    }

    /// Every member entry name across all sets, in set order.
    pub fn member_names(&self) -> Vec<String> {
        self.sets.values().flat_map(|s| s.members.iter().cloned()).collect()
    }

    pub fn set(&self, logical: &str) -> Option<&ReplicaSet> {
        self.sets.get(logical)
    }

    /// Update one member's state across every set hosting it (atomics —
    /// callable through the shared `&Router`). Returns whether any set
    /// hosts the member.
    pub fn set_member_state(&self, member: &str, state: MemberState) -> bool {
        let mut found = false;
        for set in self.sets.values() {
            for (i, m) in set.members.iter().enumerate() {
                if m == member {
                    set.set_state(i, state);
                    found = true;
                }
            }
        }
        found
    }

    /// A member's state (first set hosting it), or `None` if unrouted.
    pub fn member_state(&self, member: &str) -> Option<MemberState> {
        for set in self.sets.values() {
            for (i, m) in set.members.iter().enumerate() {
                if m == member {
                    return Some(set.member_state(i));
                }
            }
        }
        None
    }

    /// Record one request outcome into the member's circuit breaker,
    /// across every set hosting it. No-op for unrouted names and when
    /// breakers are disabled (`window == 0`). Only *member-attributable*
    /// failures should be fed here (see [`crate::error::IcrError::
    /// is_member_fault`]) — a client's shape mismatch says nothing about
    /// the member's health.
    pub fn record_outcome(&self, member: &str, ok: bool) {
        let _ = self.record_outcome_observed(member, ok);
    }

    /// As [`Router::record_outcome`], additionally reporting the first
    /// breaker transition `(from, to)` this outcome caused, so the
    /// serving layer can emit a structured `breaker_transition` event
    /// (`DESIGN.md` §13) without polling breaker states.
    pub fn record_outcome_observed(
        &self,
        member: &str,
        ok: bool,
    ) -> Option<(BreakerState, BreakerState)> {
        if self.breaker_cfg.window == 0 {
            return None;
        }
        let mut transition = None;
        for set in self.sets.values() {
            for (i, m) in set.members.iter().enumerate() {
                if m == member {
                    let mut b = set.breaker[i].lock().unwrap();
                    let from = b.state;
                    b.record(&self.breaker_cfg, ok);
                    if transition.is_none() && b.state != from {
                        transition = Some((from, b.state));
                    }
                }
            }
        }
        transition
    }

    /// A member's breaker state (first set hosting it).
    pub fn breaker_state(&self, member: &str) -> Option<BreakerState> {
        for set in self.sets.values() {
            for (i, m) in set.members.iter().enumerate() {
                if m == member {
                    return Some(set.breaker_state(i));
                }
            }
        }
        None
    }

    /// Total breaker trips of a member (first set hosting it).
    pub fn breaker_trips(&self, member: &str) -> Option<u64> {
        for set in self.sets.values() {
            for (i, m) in set.members.iter().enumerate() {
                if m == member {
                    return Some(set.breaker_trips(i));
                }
            }
        }
        None
    }

    /// Apply the routing policy to a non-empty candidate index list.
    fn pick(
        &self,
        set: &ReplicaSet,
        avail: &[usize],
        request: &Request,
        outstanding: &dyn Fn(&str) -> u64,
    ) -> usize {
        let n = avail.len();
        match self.policy {
            RoutePolicy::RoundRobin => avail[set.rr.fetch_add(1, Ordering::Relaxed) % n],
            RoutePolicy::LeastOutstanding => avail
                .iter()
                .copied()
                .min_by_key(|&i| (outstanding(&set.members[i]), i))
                .expect("candidate list is never empty"),
            RoutePolicy::SeedAffinity => match affinity_seed(request) {
                Some(seed) => avail
                    .iter()
                    .copied()
                    .max_by_key(|&i| (rendezvous_weight(seed, &set.members[i]), std::cmp::Reverse(i)))
                    .expect("candidate list is never empty"),
                None => avail[set.rr.fetch_add(1, Ordering::Relaxed) % n],
            },
        }
    }

    /// Bookkeeping for a routed selection: routed counter plus the
    /// breaker's Half-Open trial budget.
    fn note_routed(&self, set: &ReplicaSet, idx: usize) {
        set.routed[idx].fetch_add(1, Ordering::Relaxed);
        if self.breaker_cfg.window != 0 {
            set.breaker[idx].lock().unwrap().note_routed();
        }
    }

    /// Resolve a logical name to a member entry name, or `None` if the
    /// name is not a replica set. `outstanding` reports a member's
    /// currently in-flight request count (least-outstanding input).
    pub fn route(
        &self,
        logical: &str,
        request: &Request,
        outstanding: &dyn Fn(&str) -> u64,
    ) -> Option<&str> {
        let set = self.sets.get(logical)?;
        let avail = set.available(&self.breaker_cfg);
        let idx = self.pick(set, &avail, request, outstanding);
        self.note_routed(set, idx);
        Some(&set.members[idx])
    }

    /// Failover routing: like [`Router::route`], but skips the members
    /// in `exclude` (already-tried members) and returns `None` instead
    /// of falling back when no other member is available. The policy
    /// still applies among the survivors, so seed affinity re-ranks
    /// deterministically exactly as it would after an ejection.
    pub fn route_excluding(
        &self,
        logical: &str,
        request: &Request,
        outstanding: &dyn Fn(&str) -> u64,
        exclude: &[String],
    ) -> Option<&str> {
        let set = self.sets.get(logical)?;
        let avail: Vec<usize> = set
            .available(&self.breaker_cfg)
            .into_iter()
            .filter(|&i| !exclude.iter().any(|e| e == &set.members[i]))
            .collect();
        if avail.is_empty() {
            return None;
        }
        let idx = self.pick(set, &avail, request, outstanding);
        self.note_routed(set, idx);
        Some(&set.members[idx])
    }

    /// The `replica_sets` section of the `stats` document: policy plus,
    /// per set, the member list with state and routed/outstanding
    /// counters.
    pub fn to_json(&self, outstanding: &dyn Fn(&str) -> u64) -> Value {
        let mut sets: BTreeMap<String, Value> = BTreeMap::new();
        for (logical, set) in &self.sets {
            let members: Vec<Value> = set
                .members
                .iter()
                .enumerate()
                .map(|(i, m)| {
                    let breaker = set.breaker_state(i);
                    let mut fields = vec![
                        ("name", json::s(m)),
                        ("state", json::s(set.member_state(i).name())),
                        ("breaker", json::s(breaker.name())),
                        ("breaker_trips", json::num(set.breaker_trips(i) as f64)),
                        ("routed", json::num(set.routed_to(i) as f64)),
                        ("outstanding", json::num(outstanding(m) as f64)),
                    ];
                    if breaker != BreakerState::Closed {
                        // Typed reason: why selection is skipping (or
                        // only trialing) a probe-healthy member.
                        fields.insert(3, ("breaker_reason", json::s("member_tripped")));
                    }
                    json::obj(fields)
                })
                .collect();
            sets.insert(logical.clone(), json::obj(vec![("members", json::arr(members))]));
        }
        json::obj(vec![
            ("policy", json::s(self.policy.name())),
            ("sets", Value::Object(sets)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn members(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("gp@{i}")).collect()
    }

    fn sample(seed: u64) -> Request {
        Request::Sample { count: 1, seed }
    }

    fn seed_router(policy: RoutePolicy, n: usize) -> Router {
        let mut r = Router::new(policy);
        r.add_set("gp", members(n));
        r
    }

    #[test]
    fn policy_names_roundtrip() {
        for p in RoutePolicy::ALL {
            assert_eq!(RoutePolicy::parse(p.name()).unwrap(), p);
        }
        assert!(RoutePolicy::parse("random").is_err());
        assert_eq!(RoutePolicy::default(), RoutePolicy::SeedAffinity);
    }

    #[test]
    fn round_robin_rotates() {
        let r = seed_router(RoutePolicy::RoundRobin, 3);
        let none = |_: &str| 0u64;
        let picks: Vec<String> = (0..6)
            .map(|i| r.route("gp", &sample(i), &none).unwrap().to_string())
            .collect();
        assert_eq!(picks, ["gp@0", "gp@1", "gp@2", "gp@0", "gp@1", "gp@2"]);
        assert!(r.route("other", &sample(0), &none).is_none());
    }

    #[test]
    fn least_outstanding_picks_the_idle_member() {
        let r = seed_router(RoutePolicy::LeastOutstanding, 3);
        let load = |m: &str| match m {
            "gp@0" => 5,
            "gp@1" => 1,
            _ => 9,
        };
        assert_eq!(r.route("gp", &sample(0), &load).unwrap(), "gp@1");
        // Ties resolve to the lowest index.
        let flat = |_: &str| 2u64;
        assert_eq!(r.route("gp", &sample(0), &flat).unwrap(), "gp@0");
    }

    #[test]
    fn seed_affinity_is_stable_per_seed_and_covers_members() {
        let r = seed_router(RoutePolicy::SeedAffinity, 3);
        let none = |_: &str| 0u64;
        let mut hit = std::collections::BTreeSet::new();
        for seed in 0..64u64 {
            let first = r.route("gp", &sample(seed), &none).unwrap().to_string();
            for _ in 0..3 {
                assert_eq!(r.route("gp", &sample(seed), &none).unwrap(), first);
            }
            hit.insert(first);
        }
        // Rendezvous hashing spreads 64 seeds over all 3 members.
        assert_eq!(hit.len(), 3, "members unused: {hit:?}");
        // Unseeded requests still route (rotation fallback).
        assert!(r.route("gp", &Request::Stats, &none).is_some());
        // And a fresh identically configured router agrees exactly.
        let r2 = seed_router(RoutePolicy::SeedAffinity, 3);
        for seed in 0..64u64 {
            assert_eq!(
                r.route("gp", &sample(seed), &none).unwrap(),
                r2.route("gp", &sample(seed), &none).unwrap(),
            );
        }
    }

    #[test]
    fn prop_seed_affinity_unmoved_by_unrelated_member_additions() {
        // Rendezvous property: growing the set only moves seeds the new
        // member wins; every other seed keeps its member.
        let none = |_: &str| 0u64;
        let small = seed_router(RoutePolicy::SeedAffinity, 3);
        let grown = seed_router(RoutePolicy::SeedAffinity, 4);
        let mut moved = 0usize;
        for seed in 0..256u64 {
            let a = small.route("gp", &sample(seed), &none).unwrap().to_string();
            let b = grown.route("gp", &sample(seed), &none).unwrap().to_string();
            if b == "gp@3" {
                moved += 1;
            } else {
                assert_eq!(a, b, "seed {seed} moved between surviving members");
            }
        }
        // The new member claims roughly 1/4 of the seeds — certainly
        // neither none nor all.
        assert!(moved > 0 && moved < 256, "moved {moved}");
    }

    #[test]
    fn prop_seed_affinity_rehashes_deterministically_on_ejection() {
        let none = |_: &str| 0u64;
        let r = seed_router(RoutePolicy::SeedAffinity, 3);
        let before: Vec<String> = (0..128u64)
            .map(|s| r.route("gp", &sample(s), &none).unwrap().to_string())
            .collect();
        assert!(r.set_member_state("gp@1", MemberState::Ejected));
        for (s, old) in before.iter().enumerate() {
            let now = r.route("gp", &sample(s as u64), &none).unwrap().to_string();
            if old == "gp@1" {
                // Orphaned seeds redistribute to survivors…
                assert_ne!(now, "gp@1", "seed {s} routed to the ejected member");
            } else {
                // …while every other seed keeps its member.
                assert_eq!(&now, old, "seed {s} moved although its member survived");
            }
        }
        // Restoring the member restores the original assignment exactly.
        r.set_member_state("gp@1", MemberState::Healthy);
        for (s, old) in before.iter().enumerate() {
            assert_eq!(r.route("gp", &sample(s as u64), &none).unwrap(), old.as_str());
        }
    }

    #[test]
    fn draining_and_ejected_members_receive_no_new_traffic() {
        for policy in RoutePolicy::ALL {
            for state in [MemberState::Draining, MemberState::Ejected] {
                let r = seed_router(policy, 3);
                r.set_member_state("gp@1", state);
                let none = |_: &str| 0u64;
                for seed in 0..32u64 {
                    let pick = r.route("gp", &sample(seed), &none).unwrap();
                    assert_ne!(pick, "gp@1", "{policy:?}/{state:?} routed to unavailable member");
                }
            }
        }
        // least_outstanding must skip a drained member even when it is
        // the idlest — the satellite fix.
        let r = seed_router(RoutePolicy::LeastOutstanding, 2);
        r.set_member_state("gp@0", MemberState::Draining);
        let load = |m: &str| if m == "gp@0" { 0u64 } else { 100 };
        assert_eq!(r.route("gp", &sample(0), &load).unwrap(), "gp@1");
    }

    #[test]
    fn fully_unavailable_set_falls_back_to_all_members() {
        let r = seed_router(RoutePolicy::SeedAffinity, 2);
        r.set_member_state("gp@0", MemberState::Ejected);
        r.set_member_state("gp@1", MemberState::Ejected);
        let none = |_: &str| 0u64;
        assert!(r.route("gp", &sample(7), &none).is_some());
        assert_eq!(r.member_state("gp@0"), Some(MemberState::Ejected));
        assert_eq!(r.member_state("nope"), None);
        assert!(!r.set_member_state("nope", MemberState::Healthy));
    }

    /// A router with a fast-reacting breaker: window `w`, 50% trip
    /// ratio, zero cooldown (Half-Open on the next selection pass),
    /// one trial.
    fn breaker_router(n: usize, window: usize) -> Router {
        let mut r = Router::new(RoutePolicy::SeedAffinity);
        r.set_breaker_config(BreakerConfig {
            window,
            trip_ratio: 0.5,
            cooldown: Duration::from_millis(0),
            trials: 1,
        });
        r.add_set("gp", members(n));
        r
    }

    #[test]
    fn breaker_trips_after_a_full_window_of_failures() {
        let r = breaker_router(2, 4);
        let none = |_: &str| 0u64;
        // Three failures: window not yet full, still Closed and routable.
        for _ in 0..3 {
            r.record_outcome("gp@1", false);
        }
        assert_eq!(r.breaker_state("gp@1"), Some(BreakerState::Closed));
        r.record_outcome("gp@1", false);
        assert_eq!(r.breaker_state("gp@1"), Some(BreakerState::Open));
        assert_eq!(r.breaker_trips("gp@1"), Some(1));
        // Unrouted members have no breaker.
        assert_eq!(r.breaker_state("nope"), None);
        let _ = r.route("gp", &sample(0), &none);
        // JSON carries the typed reason (cooldown is 0, so by now the
        // routing pass above advanced the breaker to half_open).
        let v = r.to_json(&none);
        let m = v.get_path("sets.gp.members").and_then(Value::as_array).unwrap();
        assert_eq!(m[1].get("breaker_reason").and_then(Value::as_str), Some("member_tripped"));
        assert!(m[0].get("breaker_reason").is_none());
        assert_eq!(m[0].get("breaker").and_then(Value::as_str), Some("closed"));
    }

    #[test]
    fn breaker_mixed_outcomes_below_ratio_stay_closed() {
        let r = breaker_router(2, 4);
        // 1 failure in 4 (25% < 50%): stays Closed; the window slides.
        for ok in [false, true, true, true, true, false, true] {
            r.record_outcome("gp@0", ok);
        }
        assert_eq!(r.breaker_state("gp@0"), Some(BreakerState::Closed));
        assert_eq!(r.breaker_trips("gp@0"), Some(0));
    }

    #[test]
    fn tripped_member_seeds_remap_exactly_like_ejection() {
        let none = |_: &str| 0u64;
        let mut tripped = breaker_router(3, 4);
        let ejected = breaker_router(3, 4);
        let before: Vec<String> = (0..128u64)
            .map(|s| tripped.route("gp", &sample(s), &none).unwrap().to_string())
            .collect();
        for _ in 0..4 {
            tripped.record_outcome("gp@1", false);
        }
        // Pin the breaker Open for the comparison (cooldown 0 would
        // otherwise admit Half-Open trials mid-loop).
        tripped.set_breaker_config(BreakerConfig {
            cooldown: Duration::from_secs(3600),
            ..*tripped.breaker_config()
        });
        ejected.set_member_state("gp@1", MemberState::Ejected);
        for (s, old) in before.iter().enumerate() {
            let a = tripped.route("gp", &sample(s as u64), &none).unwrap().to_string();
            let b = ejected.route("gp", &sample(s as u64), &none).unwrap().to_string();
            assert_eq!(a, b, "seed {s} (was {old}) diverged between trip and ejection");
            assert_ne!(a, "gp@1", "seed {s} routed to the tripped member");
        }
    }

    #[test]
    fn half_open_admits_bounded_trials_and_recovers_or_retrips() {
        let r = breaker_router(2, 2);
        let none = |_: &str| 0u64;
        // Work out which member seed 0 pins to, then trip it.
        let pinned = r.route("gp", &sample(0), &none).unwrap().to_string();
        r.record_outcome(&pinned, false);
        r.record_outcome(&pinned, false);
        assert_eq!(r.breaker_state(&pinned), Some(BreakerState::Open));
        // Cooldown 0: the next pass admits it as a Half-Open trial and
        // seed affinity sends its pinned seed straight back.
        assert_eq!(r.route("gp", &sample(0), &none).unwrap(), pinned);
        assert_eq!(r.breaker_state(&pinned), Some(BreakerState::HalfOpen));
        // Trial budget (1) spent: the next selection skips it.
        assert_ne!(r.route("gp", &sample(0), &none).unwrap(), pinned);
        // Trial failure re-opens (counts as a second trip) …
        r.record_outcome(&pinned, false);
        assert_eq!(r.breaker_state(&pinned), Some(BreakerState::Open));
        assert_eq!(r.breaker_trips(&pinned), Some(2));
        // … and a successful trial after the next admission re-closes.
        assert_eq!(r.route("gp", &sample(0), &none).unwrap(), pinned);
        r.record_outcome(&pinned, true);
        assert_eq!(r.breaker_state(&pinned), Some(BreakerState::Closed));
        // Fully recovered: selection and a fresh window behave normally.
        assert_eq!(r.route("gp", &sample(0), &none).unwrap(), pinned);
    }

    #[test]
    fn wholly_tripped_set_still_routes() {
        // Long cooldown pins tripped breakers Open.
        let mut r = breaker_router(2, 2);
        r.set_breaker_config(BreakerConfig {
            window: 2,
            trip_ratio: 0.5,
            cooldown: Duration::from_secs(3600),
            trials: 1,
        });
        for m in ["gp@0", "gp@1"] {
            r.record_outcome(m, false);
            r.record_outcome(m, false);
            assert_eq!(r.breaker_state(m), Some(BreakerState::Open));
        }
        let none = |_: &str| 0u64;
        // Availability over purity: breakers are ignored when they
        // would blackhole the whole set.
        assert!(r.route("gp", &sample(7), &none).is_some());
    }

    #[test]
    fn disabled_breaker_never_trips_and_route_excluding_fails_over() {
        let mut r = Router::new(RoutePolicy::SeedAffinity);
        r.set_breaker_config(BreakerConfig { window: 0, ..BreakerConfig::default() });
        r.add_set("gp", members(3));
        for _ in 0..64 {
            r.record_outcome("gp@0", false);
        }
        assert_eq!(r.breaker_state("gp@0"), Some(BreakerState::Closed));

        // route_excluding skips the excluded members deterministically
        // and returns None (no fallback) once every member is excluded.
        let none = |_: &str| 0u64;
        let first = r.route("gp", &sample(3), &none).unwrap().to_string();
        let mut tried = vec![first.clone()];
        let second = r
            .route_excluding("gp", &sample(3), &none, &tried)
            .unwrap()
            .to_string();
        assert_ne!(second, first);
        tried.push(second.clone());
        let third = r
            .route_excluding("gp", &sample(3), &none, &tried)
            .unwrap()
            .to_string();
        assert!(third != first && third != second);
        tried.push(third);
        assert!(r.route_excluding("gp", &sample(3), &none, &tried).is_none());
        assert!(r.route_excluding("nope", &sample(3), &none, &[]).is_none());
    }

    #[test]
    fn routed_counters_states_and_json() {
        let r = seed_router(RoutePolicy::SeedAffinity, 2);
        let none = |_: &str| 0u64;
        let member = r.route("gp", &sample(1), &none).unwrap().to_string();
        for _ in 0..3 {
            assert_eq!(r.route("gp", &sample(1), &none).unwrap(), member);
        }
        let idx: usize = member.strip_prefix("gp@").unwrap().parse().unwrap();
        assert_eq!(r.set("gp").unwrap().routed_to(idx), 4);
        r.set_member_state("gp@0", MemberState::Draining);
        let v = r.to_json(&none);
        assert_eq!(v.get("policy").and_then(Value::as_str), Some("seed_affinity"));
        let m = v.get_path("sets.gp.members").and_then(Value::as_array).unwrap();
        assert_eq!(m.len(), 2);
        assert_eq!(m[idx].get("routed").and_then(Value::as_usize), Some(4));
        assert_eq!(m[0].get("state").and_then(Value::as_str), Some("draining"));
        assert_eq!(m[1].get("state").and_then(Value::as_str), Some("healthy"));
        assert_eq!(r.logical_names(), vec!["gp"]);
        assert_eq!(r.member_names(), vec!["gp@0", "gp@1"]);
    }
}
