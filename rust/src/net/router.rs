//! Shard/replica router over the model registry.
//!
//! `DESIGN.md` §5 reserved the hook: a shard is a named
//! [`crate::model::GpModel`] registry entry. A **replica set** groups N
//! identical entries (`--replicas gp=native:3` → members `gp@0..gp@2`)
//! under one logical name; requests addressed to the logical name are
//! routed to a member by a pluggable [`RoutePolicy`]. Requests may still
//! address a member (`gp@1`) directly — the router only resolves names
//! the registry does not already host.
//!
//! Determinism: every member of a set is built from the same
//! [`crate::config::ModelConfig`], so `sample` bytes are identical on
//! every replica regardless of the policy's choice; `seed_affinity`
//! additionally pins a given seed to a fixed member, which keeps
//! per-replica caches warm and makes the routing itself reproducible
//! (tested in `net_e2e.rs`).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

use crate::coordinator::request::Request;
use crate::json::{self, Value};

/// How a replica set picks the member serving the next request.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RoutePolicy {
    /// Strict rotation over the members.
    RoundRobin,
    /// Member with the fewest requests currently in flight (ties resolve
    /// to the lowest member index).
    LeastOutstanding,
    /// Seeded requests (`sample`, `infer_multi`) map `seed % replicas`,
    /// so a given seed always lands on the same member; unseeded
    /// requests fall back to rotation.
    #[default]
    SeedAffinity,
}

impl RoutePolicy {
    /// Every policy, in the order advertised by `icr --version` and the
    /// `stats` document.
    pub const ALL: [RoutePolicy; 3] =
        [RoutePolicy::RoundRobin, RoutePolicy::LeastOutstanding, RoutePolicy::SeedAffinity];

    pub fn parse(s: &str) -> Result<RoutePolicy, String> {
        match s {
            "round_robin" | "rr" => Ok(RoutePolicy::RoundRobin),
            "least_outstanding" | "lo" => Ok(RoutePolicy::LeastOutstanding),
            "seed_affinity" | "seed" => Ok(RoutePolicy::SeedAffinity),
            other => Err(format!(
                "unknown routing policy {other:?} (round_robin|least_outstanding|seed_affinity)"
            )),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            RoutePolicy::RoundRobin => "round_robin",
            RoutePolicy::LeastOutstanding => "least_outstanding",
            RoutePolicy::SeedAffinity => "seed_affinity",
        }
    }
}

/// One logical replica set: ordered member entry names plus routing
/// state (rotation cursor, per-member routed counters).
pub struct ReplicaSet {
    members: Vec<String>,
    rr: AtomicUsize,
    routed: Vec<AtomicU64>,
}

impl ReplicaSet {
    fn new(members: Vec<String>) -> ReplicaSet {
        let routed = members.iter().map(|_| AtomicU64::new(0)).collect();
        ReplicaSet { members, rr: AtomicUsize::new(0), routed }
    }

    pub fn members(&self) -> &[String] {
        &self.members
    }

    /// How many requests this set has routed to member `i`.
    pub fn routed_to(&self, i: usize) -> u64 {
        self.routed[i].load(Ordering::Relaxed)
    }
}

/// The seed a request pins replica affinity on, when it has one.
fn affinity_seed(request: &Request) -> Option<u64> {
    match request {
        Request::Sample { seed, .. } => Some(*seed),
        Request::InferMulti { seed, .. } => Some(*seed),
        _ => None,
    }
}

/// Maps logical replica-set names to member registry entries.
pub struct Router {
    policy: RoutePolicy,
    sets: BTreeMap<String, ReplicaSet>,
}

impl Router {
    pub fn new(policy: RoutePolicy) -> Router {
        Router { policy, sets: BTreeMap::new() }
    }

    /// Register a logical name over its (non-empty, ordered) members.
    pub fn add_set(&mut self, logical: &str, members: Vec<String>) {
        debug_assert!(!members.is_empty(), "replica set {logical:?} has no members");
        self.sets.insert(logical.to_string(), ReplicaSet::new(members));
    }

    pub fn is_empty(&self) -> bool {
        self.sets.is_empty()
    }

    pub fn policy(&self) -> RoutePolicy {
        self.policy
    }

    /// Logical names this router resolves (sorted).
    pub fn logical_names(&self) -> Vec<String> {
        self.sets.keys().cloned().collect()
    }

    pub fn set(&self, logical: &str) -> Option<&ReplicaSet> {
        self.sets.get(logical)
    }

    /// Resolve a logical name to a member entry name, or `None` if the
    /// name is not a replica set. `outstanding` reports a member's
    /// currently in-flight request count (least-outstanding input).
    pub fn route(
        &self,
        logical: &str,
        request: &Request,
        outstanding: &dyn Fn(&str) -> u64,
    ) -> Option<&str> {
        let set = self.sets.get(logical)?;
        let n = set.members.len();
        let idx = match self.policy {
            RoutePolicy::RoundRobin => set.rr.fetch_add(1, Ordering::Relaxed) % n,
            RoutePolicy::LeastOutstanding => {
                let mut best = 0usize;
                let mut best_load = u64::MAX;
                for (i, m) in set.members.iter().enumerate() {
                    let load = outstanding(m);
                    if load < best_load {
                        best = i;
                        best_load = load;
                    }
                }
                best
            }
            RoutePolicy::SeedAffinity => match affinity_seed(request) {
                Some(seed) => (seed % n as u64) as usize,
                None => set.rr.fetch_add(1, Ordering::Relaxed) % n,
            },
        };
        set.routed[idx].fetch_add(1, Ordering::Relaxed);
        Some(&set.members[idx])
    }

    /// The `replica_sets` section of the `stats` document: policy plus,
    /// per set, the member list with routed/outstanding counters.
    pub fn to_json(&self, outstanding: &dyn Fn(&str) -> u64) -> Value {
        let mut sets: BTreeMap<String, Value> = BTreeMap::new();
        for (logical, set) in &self.sets {
            let members: Vec<Value> = set
                .members
                .iter()
                .enumerate()
                .map(|(i, m)| {
                    json::obj(vec![
                        ("name", json::s(m)),
                        ("routed", json::num(set.routed_to(i) as f64)),
                        ("outstanding", json::num(outstanding(m) as f64)),
                    ])
                })
                .collect();
            sets.insert(logical.clone(), json::obj(vec![("members", json::arr(members))]));
        }
        json::obj(vec![
            ("policy", json::s(self.policy.name())),
            ("sets", Value::Object(sets)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn members(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("gp@{i}")).collect()
    }

    fn sample(seed: u64) -> Request {
        Request::Sample { count: 1, seed }
    }

    #[test]
    fn policy_names_roundtrip() {
        for p in RoutePolicy::ALL {
            assert_eq!(RoutePolicy::parse(p.name()).unwrap(), p);
        }
        assert!(RoutePolicy::parse("random").is_err());
        assert_eq!(RoutePolicy::default(), RoutePolicy::SeedAffinity);
    }

    #[test]
    fn round_robin_rotates() {
        let mut r = Router::new(RoutePolicy::RoundRobin);
        r.add_set("gp", members(3));
        let none = |_: &str| 0u64;
        let picks: Vec<String> = (0..6)
            .map(|i| r.route("gp", &sample(i), &none).unwrap().to_string())
            .collect();
        assert_eq!(picks, ["gp@0", "gp@1", "gp@2", "gp@0", "gp@1", "gp@2"]);
        assert!(r.route("other", &sample(0), &none).is_none());
    }

    #[test]
    fn least_outstanding_picks_the_idle_member() {
        let mut r = Router::new(RoutePolicy::LeastOutstanding);
        r.add_set("gp", members(3));
        let load = |m: &str| match m {
            "gp@0" => 5,
            "gp@1" => 1,
            _ => 9,
        };
        assert_eq!(r.route("gp", &sample(0), &load).unwrap(), "gp@1");
        // Ties resolve to the lowest index.
        let flat = |_: &str| 2u64;
        assert_eq!(r.route("gp", &sample(0), &flat).unwrap(), "gp@0");
    }

    #[test]
    fn seed_affinity_is_stable_per_seed() {
        let mut r = Router::new(RoutePolicy::SeedAffinity);
        r.add_set("gp", members(3));
        let none = |_: &str| 0u64;
        for seed in 0..12u64 {
            let first = r.route("gp", &sample(seed), &none).unwrap().to_string();
            for _ in 0..3 {
                assert_eq!(r.route("gp", &sample(seed), &none).unwrap(), first);
            }
            assert_eq!(first, format!("gp@{}", seed % 3));
        }
        // Unseeded requests still route (rotation fallback).
        assert!(r.route("gp", &Request::Stats, &none).is_some());
    }

    #[test]
    fn routed_counters_and_json() {
        let mut r = Router::new(RoutePolicy::SeedAffinity);
        r.add_set("gp", members(2));
        let none = |_: &str| 0u64;
        for _ in 0..4 {
            r.route("gp", &sample(1), &none);
        }
        assert_eq!(r.set("gp").unwrap().routed_to(1), 4);
        let v = r.to_json(&none);
        assert_eq!(v.get("policy").and_then(Value::as_str), Some("seed_affinity"));
        let m = v.get_path("sets.gp.members").and_then(Value::as_array).unwrap();
        assert_eq!(m.len(), 2);
        assert_eq!(m[1].get("routed").and_then(Value::as_usize), Some(4));
        assert_eq!(r.logical_names(), vec!["gp"]);
    }
}
