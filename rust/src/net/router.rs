//! Shard/replica router over the model registry.
//!
//! `DESIGN.md` §5 reserved the hook: a shard is a named
//! [`crate::model::GpModel`] registry entry. A **replica set** groups N
//! member entries (`--replicas gp=native:3` → members `gp@0..gp@2`;
//! mixed local+remote sets add `remote:tcp:HOST:PORT` members) under one
//! logical name; requests addressed to the logical name are routed to a
//! member by a pluggable [`RoutePolicy`]. Requests may still address a
//! member (`gp@1`) directly — the router only resolves names the
//! registry does not already host.
//!
//! **Member health** (`DESIGN.md` §9): every member carries a
//! [`MemberState`]. Only `Healthy` members receive newly routed traffic;
//! `Draining` members finish their in-flight work but are skipped by
//! selection, and `Ejected` members failed their health probe and are
//! skipped until a probe succeeds again. If no member is available the
//! router falls back to the full set (availability over purity — a
//! wholly ejected set keeps answering rather than blackholing).
//!
//! Determinism: every member of a set serves the same model, so `sample`
//! bytes are identical regardless of the policy's choice; `seed_affinity`
//! additionally pins a given seed to a fixed member via **rendezvous
//! (highest-random-weight) hashing** — each seed independently ranks all
//! members, so ejecting a member only moves the seeds it owned and
//! adding one only claims the seeds it now wins; assignments of
//! unrelated seeds never change (property-tested below and in
//! `cluster_e2e.rs`).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, AtomicU8, AtomicUsize, Ordering};

use crate::coordinator::request::Request;
use crate::json::{self, Value};

/// How a replica set picks the member serving the next request.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RoutePolicy {
    /// Strict rotation over the available members.
    RoundRobin,
    /// Available member with the fewest requests currently in flight
    /// (ties resolve to the lowest member index).
    LeastOutstanding,
    /// Seeded requests (`sample`, `infer_multi`) pick the member with
    /// the highest rendezvous weight for the seed, so a given seed
    /// always lands on the same member while it stays available;
    /// unseeded requests fall back to rotation.
    #[default]
    SeedAffinity,
}

impl RoutePolicy {
    /// Every policy, in the order advertised by `icr --version` and the
    /// `stats` document.
    pub const ALL: [RoutePolicy; 3] =
        [RoutePolicy::RoundRobin, RoutePolicy::LeastOutstanding, RoutePolicy::SeedAffinity];

    pub fn parse(s: &str) -> Result<RoutePolicy, String> {
        match s {
            "round_robin" | "rr" => Ok(RoutePolicy::RoundRobin),
            "least_outstanding" | "lo" => Ok(RoutePolicy::LeastOutstanding),
            "seed_affinity" | "seed" => Ok(RoutePolicy::SeedAffinity),
            other => Err(format!(
                "unknown routing policy {other:?} (round_robin|least_outstanding|seed_affinity)"
            )),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            RoutePolicy::RoundRobin => "round_robin",
            RoutePolicy::LeastOutstanding => "least_outstanding",
            RoutePolicy::SeedAffinity => "seed_affinity",
        }
    }
}

/// Lifecycle of a replica-set member, driven by the coordinator's health
/// monitor and by graceful drains.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MemberState {
    /// Eligible for new traffic.
    Healthy,
    /// Finishing in-flight work; selection skips it (satellite fix: a
    /// draining member used to keep receiving `least_outstanding`
    /// traffic until its session closed).
    Draining,
    /// Failed its health probe; skipped until a probe succeeds again.
    Ejected,
}

impl MemberState {
    fn as_u8(self) -> u8 {
        match self {
            MemberState::Healthy => 0,
            MemberState::Draining => 1,
            MemberState::Ejected => 2,
        }
    }

    fn from_u8(b: u8) -> MemberState {
        match b {
            1 => MemberState::Draining,
            2 => MemberState::Ejected,
            _ => MemberState::Healthy,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            MemberState::Healthy => "healthy",
            MemberState::Draining => "draining",
            MemberState::Ejected => "ejected",
        }
    }
}

/// One logical replica set: ordered member entry names plus routing
/// state (rotation cursor, per-member routed counters, member states).
pub struct ReplicaSet {
    members: Vec<String>,
    rr: AtomicUsize,
    routed: Vec<AtomicU64>,
    state: Vec<AtomicU8>,
}

impl ReplicaSet {
    fn new(members: Vec<String>) -> ReplicaSet {
        let routed = members.iter().map(|_| AtomicU64::new(0)).collect();
        let state = members.iter().map(|_| AtomicU8::new(0)).collect();
        ReplicaSet { members, rr: AtomicUsize::new(0), routed, state }
    }

    pub fn members(&self) -> &[String] {
        &self.members
    }

    /// How many requests this set has routed to member `i`.
    pub fn routed_to(&self, i: usize) -> u64 {
        self.routed[i].load(Ordering::Relaxed)
    }

    pub fn member_state(&self, i: usize) -> MemberState {
        MemberState::from_u8(self.state[i].load(Ordering::SeqCst))
    }

    fn set_state(&self, i: usize, s: MemberState) {
        self.state[i].store(s.as_u8(), Ordering::SeqCst);
    }

    /// Indices of members eligible for new traffic. Falls back to every
    /// member when none is healthy, so a fully ejected set still routes.
    fn available(&self) -> Vec<usize> {
        let healthy: Vec<usize> = (0..self.members.len())
            .filter(|&i| self.member_state(i) == MemberState::Healthy)
            .collect();
        if healthy.is_empty() {
            (0..self.members.len()).collect()
        } else {
            healthy
        }
    }
}

/// The seed a request pins replica affinity on, when it has one.
fn affinity_seed(request: &Request) -> Option<u64> {
    match request {
        Request::Sample { seed, .. } => Some(*seed),
        Request::InferMulti { seed, .. } => Some(*seed),
        _ => None,
    }
}

/// Deterministic rendezvous (highest-random-weight) score: FNV-1a over
/// the member name, mixed with the seed through a splitmix64 finalizer.
/// Each (seed, member) pair scores independently, which is what makes
/// assignments of unrelated seeds immune to membership changes.
fn rendezvous_weight(seed: u64, member: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in member.as_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    let mut z = h ^ seed.wrapping_mul(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Maps logical replica-set names to member registry entries.
pub struct Router {
    policy: RoutePolicy,
    sets: BTreeMap<String, ReplicaSet>,
}

impl Router {
    pub fn new(policy: RoutePolicy) -> Router {
        Router { policy, sets: BTreeMap::new() }
    }

    /// Register a logical name over its (non-empty, ordered) members.
    pub fn add_set(&mut self, logical: &str, members: Vec<String>) {
        debug_assert!(!members.is_empty(), "replica set {logical:?} has no members");
        self.sets.insert(logical.to_string(), ReplicaSet::new(members));
    }

    pub fn is_empty(&self) -> bool {
        self.sets.is_empty()
    }

    pub fn policy(&self) -> RoutePolicy {
        self.policy
    }

    /// Logical names this router resolves (sorted).
    pub fn logical_names(&self) -> Vec<String> {
        self.sets.keys().cloned().collect()
    }

    /// Every member entry name across all sets, in set order.
    pub fn member_names(&self) -> Vec<String> {
        self.sets.values().flat_map(|s| s.members.iter().cloned()).collect()
    }

    pub fn set(&self, logical: &str) -> Option<&ReplicaSet> {
        self.sets.get(logical)
    }

    /// Update one member's state across every set hosting it (atomics —
    /// callable through the shared `&Router`). Returns whether any set
    /// hosts the member.
    pub fn set_member_state(&self, member: &str, state: MemberState) -> bool {
        let mut found = false;
        for set in self.sets.values() {
            for (i, m) in set.members.iter().enumerate() {
                if m == member {
                    set.set_state(i, state);
                    found = true;
                }
            }
        }
        found
    }

    /// A member's state (first set hosting it), or `None` if unrouted.
    pub fn member_state(&self, member: &str) -> Option<MemberState> {
        for set in self.sets.values() {
            for (i, m) in set.members.iter().enumerate() {
                if m == member {
                    return Some(set.member_state(i));
                }
            }
        }
        None
    }

    /// Resolve a logical name to a member entry name, or `None` if the
    /// name is not a replica set. `outstanding` reports a member's
    /// currently in-flight request count (least-outstanding input).
    pub fn route(
        &self,
        logical: &str,
        request: &Request,
        outstanding: &dyn Fn(&str) -> u64,
    ) -> Option<&str> {
        let set = self.sets.get(logical)?;
        let avail = set.available();
        let n = avail.len();
        let idx = match self.policy {
            RoutePolicy::RoundRobin => avail[set.rr.fetch_add(1, Ordering::Relaxed) % n],
            RoutePolicy::LeastOutstanding => avail
                .iter()
                .copied()
                .min_by_key(|&i| (outstanding(&set.members[i]), i))
                .expect("available() is never empty"),
            RoutePolicy::SeedAffinity => match affinity_seed(request) {
                Some(seed) => avail
                    .iter()
                    .copied()
                    .max_by_key(|&i| (rendezvous_weight(seed, &set.members[i]), std::cmp::Reverse(i)))
                    .expect("available() is never empty"),
                None => avail[set.rr.fetch_add(1, Ordering::Relaxed) % n],
            },
        };
        set.routed[idx].fetch_add(1, Ordering::Relaxed);
        Some(&set.members[idx])
    }

    /// The `replica_sets` section of the `stats` document: policy plus,
    /// per set, the member list with state and routed/outstanding
    /// counters.
    pub fn to_json(&self, outstanding: &dyn Fn(&str) -> u64) -> Value {
        let mut sets: BTreeMap<String, Value> = BTreeMap::new();
        for (logical, set) in &self.sets {
            let members: Vec<Value> = set
                .members
                .iter()
                .enumerate()
                .map(|(i, m)| {
                    json::obj(vec![
                        ("name", json::s(m)),
                        ("state", json::s(set.member_state(i).name())),
                        ("routed", json::num(set.routed_to(i) as f64)),
                        ("outstanding", json::num(outstanding(m) as f64)),
                    ])
                })
                .collect();
            sets.insert(logical.clone(), json::obj(vec![("members", json::arr(members))]));
        }
        json::obj(vec![
            ("policy", json::s(self.policy.name())),
            ("sets", Value::Object(sets)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn members(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("gp@{i}")).collect()
    }

    fn sample(seed: u64) -> Request {
        Request::Sample { count: 1, seed }
    }

    fn seed_router(policy: RoutePolicy, n: usize) -> Router {
        let mut r = Router::new(policy);
        r.add_set("gp", members(n));
        r
    }

    #[test]
    fn policy_names_roundtrip() {
        for p in RoutePolicy::ALL {
            assert_eq!(RoutePolicy::parse(p.name()).unwrap(), p);
        }
        assert!(RoutePolicy::parse("random").is_err());
        assert_eq!(RoutePolicy::default(), RoutePolicy::SeedAffinity);
    }

    #[test]
    fn round_robin_rotates() {
        let r = seed_router(RoutePolicy::RoundRobin, 3);
        let none = |_: &str| 0u64;
        let picks: Vec<String> = (0..6)
            .map(|i| r.route("gp", &sample(i), &none).unwrap().to_string())
            .collect();
        assert_eq!(picks, ["gp@0", "gp@1", "gp@2", "gp@0", "gp@1", "gp@2"]);
        assert!(r.route("other", &sample(0), &none).is_none());
    }

    #[test]
    fn least_outstanding_picks_the_idle_member() {
        let r = seed_router(RoutePolicy::LeastOutstanding, 3);
        let load = |m: &str| match m {
            "gp@0" => 5,
            "gp@1" => 1,
            _ => 9,
        };
        assert_eq!(r.route("gp", &sample(0), &load).unwrap(), "gp@1");
        // Ties resolve to the lowest index.
        let flat = |_: &str| 2u64;
        assert_eq!(r.route("gp", &sample(0), &flat).unwrap(), "gp@0");
    }

    #[test]
    fn seed_affinity_is_stable_per_seed_and_covers_members() {
        let r = seed_router(RoutePolicy::SeedAffinity, 3);
        let none = |_: &str| 0u64;
        let mut hit = std::collections::BTreeSet::new();
        for seed in 0..64u64 {
            let first = r.route("gp", &sample(seed), &none).unwrap().to_string();
            for _ in 0..3 {
                assert_eq!(r.route("gp", &sample(seed), &none).unwrap(), first);
            }
            hit.insert(first);
        }
        // Rendezvous hashing spreads 64 seeds over all 3 members.
        assert_eq!(hit.len(), 3, "members unused: {hit:?}");
        // Unseeded requests still route (rotation fallback).
        assert!(r.route("gp", &Request::Stats, &none).is_some());
        // And a fresh identically configured router agrees exactly.
        let r2 = seed_router(RoutePolicy::SeedAffinity, 3);
        for seed in 0..64u64 {
            assert_eq!(
                r.route("gp", &sample(seed), &none).unwrap(),
                r2.route("gp", &sample(seed), &none).unwrap(),
            );
        }
    }

    #[test]
    fn prop_seed_affinity_unmoved_by_unrelated_member_additions() {
        // Rendezvous property: growing the set only moves seeds the new
        // member wins; every other seed keeps its member.
        let none = |_: &str| 0u64;
        let small = seed_router(RoutePolicy::SeedAffinity, 3);
        let grown = seed_router(RoutePolicy::SeedAffinity, 4);
        let mut moved = 0usize;
        for seed in 0..256u64 {
            let a = small.route("gp", &sample(seed), &none).unwrap().to_string();
            let b = grown.route("gp", &sample(seed), &none).unwrap().to_string();
            if b == "gp@3" {
                moved += 1;
            } else {
                assert_eq!(a, b, "seed {seed} moved between surviving members");
            }
        }
        // The new member claims roughly 1/4 of the seeds — certainly
        // neither none nor all.
        assert!(moved > 0 && moved < 256, "moved {moved}");
    }

    #[test]
    fn prop_seed_affinity_rehashes_deterministically_on_ejection() {
        let none = |_: &str| 0u64;
        let r = seed_router(RoutePolicy::SeedAffinity, 3);
        let before: Vec<String> = (0..128u64)
            .map(|s| r.route("gp", &sample(s), &none).unwrap().to_string())
            .collect();
        assert!(r.set_member_state("gp@1", MemberState::Ejected));
        for (s, old) in before.iter().enumerate() {
            let now = r.route("gp", &sample(s as u64), &none).unwrap().to_string();
            if old == "gp@1" {
                // Orphaned seeds redistribute to survivors…
                assert_ne!(now, "gp@1", "seed {s} routed to the ejected member");
            } else {
                // …while every other seed keeps its member.
                assert_eq!(&now, old, "seed {s} moved although its member survived");
            }
        }
        // Restoring the member restores the original assignment exactly.
        r.set_member_state("gp@1", MemberState::Healthy);
        for (s, old) in before.iter().enumerate() {
            assert_eq!(r.route("gp", &sample(s as u64), &none).unwrap(), old.as_str());
        }
    }

    #[test]
    fn draining_and_ejected_members_receive_no_new_traffic() {
        for policy in RoutePolicy::ALL {
            for state in [MemberState::Draining, MemberState::Ejected] {
                let r = seed_router(policy, 3);
                r.set_member_state("gp@1", state);
                let none = |_: &str| 0u64;
                for seed in 0..32u64 {
                    let pick = r.route("gp", &sample(seed), &none).unwrap();
                    assert_ne!(pick, "gp@1", "{policy:?}/{state:?} routed to unavailable member");
                }
            }
        }
        // least_outstanding must skip a drained member even when it is
        // the idlest — the satellite fix.
        let r = seed_router(RoutePolicy::LeastOutstanding, 2);
        r.set_member_state("gp@0", MemberState::Draining);
        let load = |m: &str| if m == "gp@0" { 0u64 } else { 100 };
        assert_eq!(r.route("gp", &sample(0), &load).unwrap(), "gp@1");
    }

    #[test]
    fn fully_unavailable_set_falls_back_to_all_members() {
        let r = seed_router(RoutePolicy::SeedAffinity, 2);
        r.set_member_state("gp@0", MemberState::Ejected);
        r.set_member_state("gp@1", MemberState::Ejected);
        let none = |_: &str| 0u64;
        assert!(r.route("gp", &sample(7), &none).is_some());
        assert_eq!(r.member_state("gp@0"), Some(MemberState::Ejected));
        assert_eq!(r.member_state("nope"), None);
        assert!(!r.set_member_state("nope", MemberState::Healthy));
    }

    #[test]
    fn routed_counters_states_and_json() {
        let r = seed_router(RoutePolicy::SeedAffinity, 2);
        let none = |_: &str| 0u64;
        let member = r.route("gp", &sample(1), &none).unwrap().to_string();
        for _ in 0..3 {
            assert_eq!(r.route("gp", &sample(1), &none).unwrap(), member);
        }
        let idx: usize = member.strip_prefix("gp@").unwrap().parse().unwrap();
        assert_eq!(r.set("gp").unwrap().routed_to(idx), 4);
        r.set_member_state("gp@0", MemberState::Draining);
        let v = r.to_json(&none);
        assert_eq!(v.get("policy").and_then(Value::as_str), Some("seed_affinity"));
        let m = v.get_path("sets.gp.members").and_then(Value::as_array).unwrap();
        assert_eq!(m.len(), 2);
        assert_eq!(m[idx].get("routed").and_then(Value::as_usize), Some(4));
        assert_eq!(m[0].get("state").and_then(Value::as_str), Some("draining"));
        assert_eq!(m[1].get("state").and_then(Value::as_str), Some("healthy"));
        assert_eq!(r.logical_names(), vec!["gp"]);
        assert_eq!(r.member_names(), vec!["gp@0", "gp@1"]);
    }
}
