//! Event-driven serving core (`DESIGN.md` §11): one thread owns every
//! connection socket behind an epoll/poll readiness loop.
//!
//! Where the legacy [`super::session`] host spends two OS threads per
//! connection (reader + writer, each waking every `--io-poll-ms` even
//! when idle), this loop registers every accepted socket non-blocking
//! with the [`super::poller`] and sleeps until something is actually
//! ready — so thousands of idle connections cost zero wakeups and two
//! `ConnState` buffers each, not two stacks.
//!
//! Per readiness cycle the loop: accepts pending connections (refusing
//! over-cap ones with the same typed `overloaded` frame as the threaded
//! host), frames complete JSONL lines out of per-connection read
//! buffers and submits them to the coordinator with a [`ReplySlot`]
//! sink, drains the completion queue those sinks feed (each completion
//! wakes the loop through the self-pipe [`super::poller::Waker`]), and
//! flushes per-connection write buffers as sockets accept bytes.
//!
//! The wire contracts are identical to the threaded host, asserted by
//! `net_e2e.rs` running both modes:
//!
//! - **In-order demux.** Every parsed frame takes the connection's next
//!   sequence number; replies are encoded strictly from the front of
//!   the per-connection pending queue, so a client sees responses in
//!   submission order no matter how the batcher reorders execution.
//!   Parse-time errors occupy a sequence slot with a pre-set result —
//!   serialized behind earlier replies exactly like `Outgoing::Ready`.
//! - **Backpressure.** Coordinator queue overflow completes inline with
//!   a typed `overloaded` error (via the sink, in order). A peer that
//!   stops draining its replies grows its write buffer to a high-water
//!   mark, after which the loop pauses *reading* that connection —
//!   bounded memory per slow client, with TCP pushing back upstream.
//! - **Idle timeout.** A timeout wheel (deadline-ordered map) arms one
//!   deadline per connection; firing closes quiet connections with
//!   nothing in flight and lazily re-arms busy ones. No per-connection
//!   poll loops.
//! - **Graceful drain.** On shutdown/SIGINT the loop stops accepting
//!   and reading, answers everything already submitted, flushes every
//!   write buffer, then hangs up and returns.

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::io::{self, Read, Write};
use std::os::unix::io::AsRawFd;
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::{protocol, Coordinator, ReplySlot, Response};
use crate::error::IcrError;
use crate::metrics::Registry;

use super::poller::{PollEvent, Poller, Waker};
use super::transport::{refuse, sigint_requested, NetServer};

/// Token of the listening socket.
const LISTENER: u64 = 0;
/// Token of the waker pipe's read end.
const WAKER: u64 = 1;
/// Token of the optional `--metrics-listen` scrape listener
/// (`DESIGN.md` §13): accept readiness rides the same epoll set, so an
/// idle endpoint costs zero wakeups; each accepted scrape is answered
/// on a short-lived thread so a slow scraper can never stall the
/// serving loop.
const METRICS: u64 = 2;
/// First connection token; monotonically increasing, never reused, so
/// a stale completion can never be delivered to a recycled connection.
const FIRST_CONN: u64 = 3;

/// Per-readiness-visit read budget. Level-triggered polling re-arms
/// immediately, so capping the bytes taken per visit bounds how long
/// one firehose connection can starve the rest of the loop.
const READ_BUDGET: usize = 256 * 1024;

/// Buffered-reply bytes above which a connection's reads are paused
/// (the peer is not draining); reads resume below the low-water mark.
const WRITE_HIGH_WATER: usize = 1 << 20;
const WRITE_LOW_WATER: usize = WRITE_HIGH_WATER / 2;

/// Upper bound on the poll timeout so the drain flag is observed
/// promptly even with no traffic and no idle deadlines due.
const POLL_CAP: Duration = Duration::from_millis(25);

/// What a [`ReplySlot`] sink delivers back to the loop: connection
/// token, per-connection sequence number, and the result.
type Completion = (u64, u64, Result<Response, IcrError>);

/// One submitted frame awaiting its reply, in submission order.
struct PendingReply {
    version: u64,
    id: u64,
    /// Raw coordinator request id — the key under which a finished
    /// span tree is stashed for echo (distinct from `id`, which echoes
    /// the client's correlation id when one was supplied).
    req_id: u64,
    /// Frame carried a trace context: pop the span-tree echo at encode
    /// time. Stays `false` for untraced frames so their replies are
    /// byte-identical to pre-observability builds.
    want_trace: bool,
    /// `None` for parse-time error frames (encoded without a model
    /// tag, like the threaded host's `Outgoing::Ready`).
    model: Option<String>,
    /// Filled by a completion; the front of the queue flushes once set.
    result: Option<Result<Response, IcrError>>,
}

/// Per-connection state: the non-blocking socket plus its framing and
/// demux buffers.
struct ConnState {
    conn: super::transport::Conn,
    /// Partial-frame bytes awaiting a newline.
    rbuf: Vec<u8>,
    /// Encoded reply bytes the socket has not accepted yet.
    wbuf: Vec<u8>,
    /// Consumed prefix of `wbuf` (compacted once fully written).
    wpos: usize,
    /// Submitted frames in order; sequence numbers are contiguous, so
    /// a completion for `seq` lives at index `seq - front_seq`.
    pending: VecDeque<PendingReply>,
    /// Sequence number the next submitted frame will take.
    next_seq: u64,
    /// Sequence number of `pending.front()`.
    front_seq: u64,
    /// Last client activity (bytes received count, like the threaded
    /// reader's partial-frame rule).
    last_active: Instant,
    /// Armed idle-wheel deadline, if any (the wheel key is
    /// `(deadline, token)`).
    idle_at: Option<Instant>,
    /// EOF seen, peer dead, or server draining: stop reading; the
    /// connection closes once `pending` and `wbuf` are empty.
    closing: bool,
    /// Reads paused by write-buffer high water.
    read_paused: bool,
    /// Current poller interest (cached to skip redundant syscalls).
    want_read: bool,
    want_write: bool,
}

impl ConnState {
    fn buffered_out(&self) -> usize {
        self.wbuf.len() - self.wpos
    }

    fn finished(&self) -> bool {
        self.closing && self.pending.is_empty() && self.buffered_out() == 0
    }
}

/// Run the readiness loop until a drain completes. Consumes the server;
/// the coordinator is left running (the caller owns its shutdown).
pub(crate) fn run(server: NetServer) -> Result<()> {
    let transport = server.coord.transport_metrics().clone();
    let coord = server.coord.clone();
    let mut poller = Poller::new().context("creating readiness poller")?;
    let waker = Arc::new(Waker::new().context("creating event-loop waker")?);
    let (done_tx, done_rx) = mpsc::channel::<Completion>();

    poller
        .register(server.listener.as_raw_fd(), LISTENER, true, false)
        .context("registering listener")?;
    poller
        .register(waker.read_fd(), WAKER, true, false)
        .context("registering waker")?;
    transport.gauge("event_loop").set(1.0);
    transport.gauge("fds_registered").set(2.0);
    if let Some(m) = &server.metrics_listener {
        poller
            .register(m.as_raw_fd(), METRICS, true, false)
            .context("registering metrics listener")?;
        transport.gauge("fds_registered").inc();
    }

    let mut conns: HashMap<u64, ConnState> = HashMap::new();
    let mut idle: BTreeMap<(Instant, u64), ()> = BTreeMap::new();
    let mut next_token: u64 = FIRST_CONN;
    let mut events: Vec<PollEvent> = Vec::new();
    let mut dirty: Vec<u64> = Vec::new();
    let mut draining = false;

    loop {
        // Enter drain mode once: stop reading everywhere; what was
        // already submitted still completes and flushes below.
        if !draining && (server.shutdown.load(Ordering::SeqCst) || sigint_requested()) {
            draining = true;
            for (&token, c) in conns.iter_mut() {
                c.closing = true;
                dirty.push(token);
            }
        }
        if draining && conns.is_empty() {
            break;
        }

        // Sleep until readiness, the next idle deadline, or the cap.
        let mut timeout = POLL_CAP;
        if let Some((&(deadline, _), _)) = idle.iter().next() {
            let now = Instant::now();
            timeout = timeout.min(deadline.saturating_duration_since(now));
        }
        poller.wait(Some(timeout), &mut events).context("polling readiness")?;
        transport.counter("event_wakeups").inc();

        for ev in &events {
            match ev.token {
                LISTENER => {
                    accept_ready(
                        &server,
                        &mut poller,
                        &mut conns,
                        &mut idle,
                        &mut next_token,
                        &transport,
                        draining,
                    )?;
                }
                WAKER => waker.drain(),
                METRICS => metrics_ready(&server, &coord, &transport),
                token => {
                    if let Some(c) = conns.get_mut(&token) {
                        if ev.readable {
                            read_ready(c, token, &coord, &transport, &done_tx, &waker);
                        }
                        dirty.push(token);
                    }
                }
            }
        }

        // Deliver completed results into their demux slots. Sequence
        // numbers are contiguous per connection, so the slot index is a
        // subtraction; completions for already-dropped connections (or
        // already-cleared queues) fall through harmlessly.
        while let Ok((token, seq, result)) = done_rx.try_recv() {
            if let Some(c) = conns.get_mut(&token) {
                if let Some(slot) = seq
                    .checked_sub(c.front_seq)
                    .and_then(|i| c.pending.get_mut(i as usize))
                {
                    if slot.result.is_none() {
                        slot.result = Some(result);
                    }
                }
                dirty.push(token);
            }
        }

        // Flush every connection something happened to this cycle.
        dirty.sort_unstable();
        dirty.dedup();
        for token in dirty.drain(..) {
            let mut done = false;
            if let Some(c) = conns.get_mut(&token) {
                flush_conn(c, &coord, &transport);
                done = c.finished();
                if !done {
                    let buffered = c.buffered_out();
                    if c.read_paused && buffered <= WRITE_LOW_WATER {
                        c.read_paused = false;
                    } else if !c.read_paused && buffered >= WRITE_HIGH_WATER {
                        c.read_paused = true;
                    }
                    update_interest(&mut poller, c, token);
                }
            }
            if done {
                close_conn(&mut poller, &mut conns, &mut idle, &transport, token);
            }
        }

        // Fire due idle deadlines: close quiet connections, lazily
        // re-arm active or busy ones from their last activity.
        if !server.idle_timeout.is_zero() {
            let now = Instant::now();
            while let Some((&(deadline, token), _)) = idle.iter().next() {
                if deadline > now {
                    break;
                }
                idle.remove(&(deadline, token));
                let mut close_idle = false;
                if let Some(c) = conns.get_mut(&token) {
                    c.idle_at = None;
                    let quiet = !c.closing
                        && c.pending.is_empty()
                        && c.buffered_out() == 0
                        && c.rbuf.is_empty();
                    if quiet && now.duration_since(c.last_active) >= server.idle_timeout {
                        transport.counter("connections_idle_closed").inc();
                        close_idle = true;
                    } else {
                        arm_idle(&mut idle, c, token, server.idle_timeout);
                    }
                }
                if close_idle {
                    close_conn(&mut poller, &mut conns, &mut idle, &transport, token);
                }
            }
        }
    }

    transport.gauge("event_loop").set(0.0);
    if let Some(path) = &server.unix_path {
        std::fs::remove_file(path).ok();
    }
    Ok(())
}

/// Accept until the listener would block. Over-cap (or draining)
/// connections are refused with the typed `overloaded` frame and
/// closed, mirroring the threaded accept loop.
fn accept_ready(
    server: &NetServer,
    poller: &mut Poller,
    conns: &mut HashMap<u64, ConnState>,
    idle: &mut BTreeMap<(Instant, u64), ()>,
    next_token: &mut u64,
    transport: &Registry,
    draining: bool,
) -> Result<()> {
    loop {
        match server.listener.accept(false) {
            Ok(conn) => {
                transport.counter("connections_total").inc();
                if draining || conns.len() >= server.max_connections {
                    transport.counter("connections_rejected").inc();
                    refuse(conn, conns.len(), server.max_connections);
                    continue;
                }
                let token = *next_token;
                *next_token += 1;
                let fd = conn.as_raw_fd();
                let mut c = ConnState {
                    conn,
                    rbuf: Vec::new(),
                    wbuf: Vec::new(),
                    wpos: 0,
                    pending: VecDeque::new(),
                    next_seq: 0,
                    front_seq: 0,
                    last_active: Instant::now(),
                    idle_at: None,
                    closing: false,
                    read_paused: false,
                    want_read: true,
                    want_write: false,
                };
                poller.register(fd, token, true, false).context("registering connection")?;
                if !server.idle_timeout.is_zero() {
                    arm_idle(idle, &mut c, token, server.idle_timeout);
                }
                conns.insert(token, c);
                transport.gauge("connections_open").inc();
                transport.gauge("fds_registered").inc();
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e).context("accepting connection"),
        }
    }
    Ok(())
}

/// Accept pending scrape connections off the `--metrics-listen`
/// socket and answer each on a short-lived thread. Serving a scrape
/// does blocking reads (bounded by a 2 s timeout), which must never
/// stall the readiness loop; scrapes are rare (typically one every
/// 15–60 s), so a throwaway thread per exchange is the cheap option
/// that keeps the loop wait-free.
fn metrics_ready(server: &NetServer, coord: &Arc<Coordinator>, transport: &Registry) {
    let Some(listener) = &server.metrics_listener else { return };
    loop {
        match listener.accept() {
            Ok((mut conn, _)) => {
                transport.counter("metrics_scrapes").inc();
                let coord = coord.clone();
                let _ = std::thread::Builder::new().name("icr-metrics-scrape".into()).spawn(
                    move || {
                        let _ = crate::obs::serve_scrape(&mut conn, &|| coord.render_prometheus());
                    },
                );
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(_) => break,
        }
    }
}

/// Arm (or re-arm) a connection's idle deadline. Deadlines in the past
/// — a connection that has been busy past its window — re-arm a full
/// window out; the firing check against `last_active` still closes it
/// as soon as a fired deadline finds it quiet.
fn arm_idle(
    idle: &mut BTreeMap<(Instant, u64), ()>,
    c: &mut ConnState,
    token: u64,
    timeout: Duration,
) {
    if let Some(at) = c.idle_at.take() {
        idle.remove(&(at, token));
    }
    let now = Instant::now();
    let mut deadline = c.last_active + timeout;
    if deadline <= now {
        deadline = now + timeout;
    }
    idle.insert((deadline, token), ());
    c.idle_at = Some(deadline);
}

/// Read until the socket would block (or the per-visit budget is
/// spent), then frame and submit every complete line. EOF and read
/// errors mark the connection closing; buffered replies still flush.
fn read_ready(
    c: &mut ConnState,
    token: u64,
    coord: &Arc<Coordinator>,
    transport: &Registry,
    done_tx: &mpsc::Sender<Completion>,
    waker: &Arc<Waker>,
) {
    if c.closing || c.read_paused {
        return;
    }
    let mut buf = [0u8; 8192];
    let mut total = 0usize;
    let mut eof = false;
    loop {
        match c.conn.read(&mut buf) {
            Ok(0) => {
                eof = true;
                break;
            }
            Ok(n) => {
                c.last_active = Instant::now();
                c.rbuf.extend_from_slice(&buf[..n]);
                total += n;
                if total >= READ_BUDGET {
                    break;
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(_) => {
                eof = true;
                break;
            }
        }
    }
    transport.gauge("read_buf_hwm_bytes").set_max(c.rbuf.len() as f64);
    // Frame complete lines; on EOF a trailing unterminated line still
    // counts as a frame (same as the threaded `LineReader`).
    while let Some(pos) = c.rbuf.iter().position(|&b| b == b'\n') {
        let rest = c.rbuf.split_off(pos + 1);
        let mut line = std::mem::replace(&mut c.rbuf, rest);
        line.pop();
        submit_line(c, line, token, coord, transport, done_tx, waker);
    }
    if eof {
        if !c.rbuf.is_empty() {
            let line = std::mem::take(&mut c.rbuf);
            submit_line(c, line, token, coord, transport, done_tx, waker);
        }
        c.closing = true;
    }
}

/// Parse one framed line and submit it, appending its demux slot to the
/// connection's pending queue. Empty lines are skipped without taking a
/// sequence number; malformed lines take one with a pre-set error.
fn submit_line(
    c: &mut ConnState,
    mut line: Vec<u8>,
    token: u64,
    coord: &Arc<Coordinator>,
    transport: &Registry,
    done_tx: &mpsc::Sender<Completion>,
    waker: &Arc<Waker>,
) {
    if line.last() == Some(&b'\r') {
        line.pop();
    }
    let line = String::from_utf8_lossy(&line).into_owned();
    if line.trim().is_empty() {
        return;
    }
    transport.counter("frames_in").inc();
    match protocol::parse_request(&line) {
        Ok(frame) => {
            let seq = c.next_seq;
            c.next_seq += 1;
            let model = frame.model.clone().unwrap_or_else(|| coord.default_model().to_string());
            let tx = done_tx.clone();
            let wk = waker.clone();
            let slot = ReplySlot::sink(move |result| {
                // A dropped receiver means the loop already exited (the
                // connection's replies can no longer be delivered).
                let _ = tx.send((token, seq, result));
                wk.wake();
            });
            // Inline fast paths (cache hit, unknown model, overload)
            // complete through the sink before this returns; the demux
            // entry is pushed first so the completion finds its slot.
            let want_trace = frame.wants_trace();
            c.pending.push_back(PendingReply {
                version: frame.version,
                id: 0, // patched below once the request id is known
                req_id: 0,
                want_trace,
                model: Some(model),
                result: None,
            });
            let id = coord.submit_sink_traced(
                frame.model.as_deref(),
                frame.request,
                slot,
                frame.trace.as_ref(),
            );
            let entry = c.pending.back_mut().expect("just pushed");
            entry.id = frame.client_id.unwrap_or(id);
            entry.req_id = id;
        }
        Err(e) => {
            c.next_seq += 1;
            let (version, id) = protocol::frame_error_context(&line);
            c.pending.push_back(PendingReply {
                version,
                id: id.unwrap_or(0),
                req_id: 0,
                want_trace: false,
                model: None,
                result: Some(Err(e)),
            });
        }
    }
}

/// Encode completed head-of-line replies into the write buffer and push
/// bytes until the socket would block. A dead peer drops the
/// connection's undelivered replies, like the threaded writer hanging
/// up on a write error.
fn flush_conn(c: &mut ConnState, coord: &Arc<Coordinator>, transport: &Registry) {
    while c.pending.front().is_some_and(|p| p.result.is_some()) {
        let p = c.pending.pop_front().expect("front checked");
        c.front_seq = c.front_seq.wrapping_add(1);
        let PendingReply { version, id, req_id, want_trace, model, result } = p;
        let result = result.expect("front checked complete");
        // The span-tree echo was stashed (keyed by the raw coordinator
        // id) before the completion was delivered, so the pop here
        // always observes it for explicitly traced requests.
        let trace = if want_trace { coord.take_trace_echo(req_id) } else { None };
        let frame = coord.with_phase("request;serialize_reply", || {
            protocol::encode_response_traced(version, id, model.as_deref(), &result, trace)
        });
        // Counted before the write so the counter is current by the
        // time a client observes the reply (same as the threaded host).
        transport.counter("frames_out").inc();
        c.wbuf.extend_from_slice(frame.to_json().as_bytes());
        c.wbuf.push(b'\n');
    }
    transport.gauge("write_buf_hwm_bytes").set_max(c.buffered_out() as f64);
    while c.wpos < c.wbuf.len() {
        match c.conn.write(&c.wbuf[c.wpos..]) {
            Ok(0) => {
                c.closing = true;
                c.pending.clear();
                c.wbuf.clear();
                c.wpos = 0;
                return;
            }
            Ok(n) => c.wpos += n,
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(_) => {
                c.closing = true;
                c.pending.clear();
                c.wbuf.clear();
                c.wpos = 0;
                return;
            }
        }
    }
    if c.wpos == c.wbuf.len() && c.wpos > 0 {
        c.wbuf.clear();
        c.wpos = 0;
    }
}

/// Reconcile the poller's interest set with what the connection needs
/// now: readable unless closing/paused, writable only while reply bytes
/// are buffered.
fn update_interest(poller: &mut Poller, c: &mut ConnState, token: u64) {
    let want_read = !c.closing && !c.read_paused;
    let want_write = c.buffered_out() > 0;
    if want_read != c.want_read || want_write != c.want_write {
        c.want_read = want_read;
        c.want_write = want_write;
        let _ = poller.modify(c.conn.as_raw_fd(), token, want_read, want_write);
    }
}

/// Remove a connection: poller deregistration, idle-wheel entry, open
/// gauges. Dropping the socket closes it (flushing nothing further).
fn close_conn(
    poller: &mut Poller,
    conns: &mut HashMap<u64, ConnState>,
    idle: &mut BTreeMap<(Instant, u64), ()>,
    transport: &Registry,
    token: u64,
) {
    if let Some(c) = conns.remove(&token) {
        poller.deregister(c.conn.as_raw_fd());
        if let Some(at) = c.idle_at {
            idle.remove(&(at, token));
        }
        transport.gauge("connections_open").dec();
        transport.gauge("fds_registered").dec();
    }
}
