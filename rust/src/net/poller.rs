//! Readiness polling substrate for the event-driven serving core
//! (`DESIGN.md` §11): a thin wrapper over `epoll(7)` on Linux with a
//! portable `poll(2)` fallback on other unix platforms, plus a
//! self-pipe [`Waker`] so coordinator worker threads can interrupt a
//! blocked wait the instant a reply completes.
//!
//! The libc symbols are declared locally — the same technique as the
//! SIGINT handler in `net/transport.rs` — so the crate keeps its
//! zero-dependency footprint. Both backends are level-triggered: an
//! event repeats every wait until the socket is drained, which lets the
//! event loop cap per-wakeup work (read budgets) without losing data.

use std::io;
use std::os::unix::io::RawFd;
use std::time::Duration;

/// One readiness report: the token the fd was registered under plus
/// which directions are ready. Error/hangup conditions surface as
/// readable-and-writable so the owner discovers them on its next
/// read/write attempt, keeping one error path.
#[derive(Debug, Clone, Copy)]
pub(crate) struct PollEvent {
    pub token: u64,
    pub readable: bool,
    pub writable: bool,
}

/// Clamp a wait timeout to whole milliseconds for the syscall, rounding
/// sub-millisecond waits *up* so a short batching window never spins.
fn timeout_ms(timeout: Option<Duration>) -> i32 {
    match timeout {
        None => -1,
        Some(d) => {
            let ms = d.as_millis().min(i32::MAX as u128) as i32;
            if ms == 0 && !d.is_zero() {
                1
            } else {
                ms
            }
        }
    }
}

#[cfg(target_os = "linux")]
mod sys {
    use super::*;

    // Mirrors the kernel's `struct epoll_event`; x86_64 declares it
    // packed (a 32-bit mask followed by an unaligned 64-bit payload).
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    #[derive(Clone, Copy)]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    const EPOLLIN: u32 = 0x001;
    const EPOLLOUT: u32 = 0x004;
    const EPOLLERR: u32 = 0x008;
    const EPOLLHUP: u32 = 0x010;
    const EPOLL_CTL_ADD: i32 = 1;
    const EPOLL_CTL_DEL: i32 = 2;
    const EPOLL_CTL_MOD: i32 = 3;
    const EPOLL_CLOEXEC: i32 = 0o2000000;

    extern "C" {
        fn epoll_create1(flags: i32) -> i32;
        fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
        fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout: i32) -> i32;
        fn close(fd: i32) -> i32;
    }

    /// Level-triggered `epoll` instance. Registration state lives in the
    /// kernel, so `wait` stays O(ready), not O(registered) — the property
    /// that lets one thread hold thousands of mostly-idle connections.
    pub(crate) struct Poller {
        epfd: RawFd,
        buf: Vec<EpollEvent>,
    }

    impl Poller {
        pub(crate) fn new() -> io::Result<Poller> {
            let epfd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
            if epfd < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(Poller { epfd, buf: vec![EpollEvent { events: 0, data: 0 }; 1024] })
        }

        fn ctl(
            &mut self,
            op: i32,
            fd: RawFd,
            token: u64,
            read: bool,
            write: bool,
        ) -> io::Result<()> {
            let mut ev = EpollEvent {
                events: if read { EPOLLIN } else { 0 } | if write { EPOLLOUT } else { 0 },
                data: token,
            };
            if unsafe { epoll_ctl(self.epfd, op, fd, &mut ev) } < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(())
        }

        pub(crate) fn register(
            &mut self,
            fd: RawFd,
            token: u64,
            read: bool,
            write: bool,
        ) -> io::Result<()> {
            self.ctl(EPOLL_CTL_ADD, fd, token, read, write)
        }

        pub(crate) fn modify(
            &mut self,
            fd: RawFd,
            token: u64,
            read: bool,
            write: bool,
        ) -> io::Result<()> {
            self.ctl(EPOLL_CTL_MOD, fd, token, read, write)
        }

        pub(crate) fn deregister(&mut self, fd: RawFd) {
            // Best effort; a closed fd is already gone from the set.
            let _ = self.ctl(EPOLL_CTL_DEL, fd, 0, false, false);
        }

        pub(crate) fn wait(
            &mut self,
            timeout: Option<Duration>,
            out: &mut Vec<PollEvent>,
        ) -> io::Result<()> {
            out.clear();
            let n = unsafe {
                epoll_wait(
                    self.epfd,
                    self.buf.as_mut_ptr(),
                    self.buf.len() as i32,
                    timeout_ms(timeout),
                )
            };
            if n < 0 {
                let e = io::Error::last_os_error();
                if e.kind() == io::ErrorKind::Interrupted {
                    return Ok(());
                }
                return Err(e);
            }
            for ev in self.buf.iter().take(n as usize) {
                // Copy out of the (possibly packed) kernel buffer before
                // touching fields — no references into packed storage.
                let ev = *ev;
                let mask = ev.events;
                let err = mask & (EPOLLERR | EPOLLHUP) != 0;
                out.push(PollEvent {
                    token: ev.data,
                    readable: mask & EPOLLIN != 0 || err,
                    writable: mask & EPOLLOUT != 0 || err,
                });
            }
            Ok(())
        }
    }

    impl Drop for Poller {
        fn drop(&mut self) {
            unsafe {
                close(self.epfd);
            }
        }
    }
}

#[cfg(all(unix, not(target_os = "linux")))]
mod sys {
    use super::*;

    // Mirrors `struct pollfd`; the constants below are the POSIX values
    // shared by the BSDs and macOS.
    #[repr(C)]
    #[derive(Clone, Copy)]
    struct PollFd {
        fd: i32,
        events: i16,
        revents: i16,
    }

    const POLLIN: i16 = 0x001;
    const POLLOUT: i16 = 0x004;
    const POLLERR: i16 = 0x008;
    const POLLHUP: i16 = 0x010;

    extern "C" {
        // `nfds_t` is `c_ulong`, which matches `usize` on every unix
        // target this crate builds for (LP64 and ILP32 alike).
        fn poll(fds: *mut PollFd, nfds: usize, timeout: i32) -> i32;
    }

    /// Portable `poll(2)` fallback: interest is kept in user space and
    /// re-submitted each wait. O(registered) per wakeup, but correct on
    /// every unix — Linux builds use the epoll backend above.
    pub(crate) struct Poller {
        fds: Vec<PollFd>,
        tokens: Vec<u64>,
    }

    impl Poller {
        pub(crate) fn new() -> io::Result<Poller> {
            Ok(Poller { fds: Vec::new(), tokens: Vec::new() })
        }

        fn events_mask(read: bool, write: bool) -> i16 {
            (if read { POLLIN } else { 0 }) | (if write { POLLOUT } else { 0 })
        }

        pub(crate) fn register(
            &mut self,
            fd: RawFd,
            token: u64,
            read: bool,
            write: bool,
        ) -> io::Result<()> {
            self.fds.push(PollFd { fd, events: Self::events_mask(read, write), revents: 0 });
            self.tokens.push(token);
            Ok(())
        }

        pub(crate) fn modify(
            &mut self,
            fd: RawFd,
            token: u64,
            read: bool,
            write: bool,
        ) -> io::Result<()> {
            match self.fds.iter().position(|p| p.fd == fd) {
                Some(i) => {
                    self.fds[i].events = Self::events_mask(read, write);
                    self.tokens[i] = token;
                    Ok(())
                }
                None => Err(io::Error::new(io::ErrorKind::NotFound, "fd not registered")),
            }
        }

        pub(crate) fn deregister(&mut self, fd: RawFd) {
            if let Some(i) = self.fds.iter().position(|p| p.fd == fd) {
                self.fds.swap_remove(i);
                self.tokens.swap_remove(i);
            }
        }

        pub(crate) fn wait(
            &mut self,
            timeout: Option<Duration>,
            out: &mut Vec<PollEvent>,
        ) -> io::Result<()> {
            out.clear();
            let n = unsafe { poll(self.fds.as_mut_ptr(), self.fds.len(), timeout_ms(timeout)) };
            if n < 0 {
                let e = io::Error::last_os_error();
                if e.kind() == io::ErrorKind::Interrupted {
                    return Ok(());
                }
                return Err(e);
            }
            for (i, p) in self.fds.iter().enumerate() {
                let mask = p.revents;
                if mask == 0 {
                    continue;
                }
                let err = mask & (POLLERR | POLLHUP) != 0;
                out.push(PollEvent {
                    token: self.tokens[i],
                    readable: mask & POLLIN != 0 || err,
                    writable: mask & POLLOUT != 0 || err,
                });
            }
            Ok(())
        }
    }
}

pub(crate) use sys::Poller;

extern "C" {
    fn pipe(fds: *mut i32) -> i32;
    fn fcntl(fd: i32, cmd: i32, arg: i32) -> i32;
    fn read(fd: i32, buf: *mut u8, count: usize) -> isize;
    fn write(fd: i32, buf: *const u8, count: usize) -> isize;
    fn close(fd: i32) -> i32;
}

const F_SETFL: i32 = 4;
#[cfg(target_os = "linux")]
const O_NONBLOCK: i32 = 0o4000;
#[cfg(not(target_os = "linux"))]
const O_NONBLOCK: i32 = 0x4;

/// Self-pipe waker: the event loop registers [`Waker::read_fd`] for
/// readability; any thread calls [`Waker::wake`] to make a blocked
/// `Poller::wait` return. Writes beyond the pipe buffer hit `EAGAIN`
/// and are dropped — one pending byte is already a wake-up.
pub(crate) struct Waker {
    read_fd: RawFd,
    write_fd: RawFd,
}

impl Waker {
    pub(crate) fn new() -> io::Result<Waker> {
        let mut fds = [0i32; 2];
        if unsafe { pipe(fds.as_mut_ptr()) } < 0 {
            return Err(io::Error::last_os_error());
        }
        for fd in fds {
            // Fresh pipe fds carry no other status flags, so a plain
            // F_SETFL to O_NONBLOCK is lossless.
            if unsafe { fcntl(fd, F_SETFL, O_NONBLOCK) } < 0 {
                let e = io::Error::last_os_error();
                unsafe {
                    close(fds[0]);
                    close(fds[1]);
                }
                return Err(e);
            }
        }
        Ok(Waker { read_fd: fds[0], write_fd: fds[1] })
    }

    pub(crate) fn read_fd(&self) -> RawFd {
        self.read_fd
    }

    /// Interrupt a blocked `wait` (callable from any thread).
    pub(crate) fn wake(&self) {
        let buf = [1u8];
        unsafe {
            write(self.write_fd, buf.as_ptr(), 1);
        }
    }

    /// Swallow accumulated wake bytes once the loop is awake.
    pub(crate) fn drain(&self) {
        let mut buf = [0u8; 64];
        loop {
            let n = unsafe { read(self.read_fd, buf.as_mut_ptr(), buf.len()) };
            if n <= 0 {
                break;
            }
        }
    }
}

impl Drop for Waker {
    fn drop(&mut self) {
        unsafe {
            close(self.read_fd);
            close(self.write_fd);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read as _, Write as _};
    use std::os::unix::io::AsRawFd;
    use std::os::unix::net::UnixStream;

    #[test]
    fn waker_wakes_a_blocked_wait() {
        let mut poller = Poller::new().expect("poller");
        let waker = Waker::new().expect("waker");
        poller.register(waker.read_fd(), 7, true, false).expect("register");
        let mut events = Vec::new();

        // Nothing pending: a short wait times out with no events.
        poller.wait(Some(Duration::from_millis(5)), &mut events).expect("wait");
        assert!(events.is_empty());

        waker.wake();
        poller.wait(Some(Duration::from_millis(1000)), &mut events).expect("wait");
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].token, 7);
        assert!(events[0].readable);

        // Drained, the pipe goes quiet again (level-triggered check).
        waker.drain();
        poller.wait(Some(Duration::from_millis(5)), &mut events).expect("wait");
        assert!(events.is_empty());
    }

    #[test]
    fn socket_readiness_and_interest_changes() {
        let (mut a, b) = UnixStream::pair().expect("socketpair");
        b.set_nonblocking(true).expect("nonblocking");
        let mut poller = Poller::new().expect("poller");
        poller.register(b.as_raw_fd(), 42, true, false).expect("register");
        let mut events = Vec::new();

        a.write_all(b"hello\n").expect("write");
        poller.wait(Some(Duration::from_millis(1000)), &mut events).expect("wait");
        assert!(events.iter().any(|e| e.token == 42 && e.readable));

        // Read interest off, write interest on: an idle healthy socket
        // reports writable immediately and stops reporting the unread
        // bytes.
        poller.modify(b.as_raw_fd(), 42, false, true).expect("modify");
        poller.wait(Some(Duration::from_millis(1000)), &mut events).expect("wait");
        assert!(events.iter().any(|e| e.token == 42 && e.writable));
        assert!(events.iter().all(|e| e.token != 42 || !e.readable));

        // Deregistered fds never fire.
        poller.deregister(b.as_raw_fd());
        poller.wait(Some(Duration::from_millis(5)), &mut events).expect("wait");
        assert!(events.is_empty());

        // Peer hangup surfaces as readiness on a registered fd, so the
        // owner's next read observes EOF.
        let (mut c, d) = UnixStream::pair().expect("socketpair");
        d.set_nonblocking(true).expect("nonblocking");
        poller.register(d.as_raw_fd(), 43, true, false).expect("register");
        c.write_all(b"x").expect("write");
        drop(c);
        poller.wait(Some(Duration::from_millis(1000)), &mut events).expect("wait");
        assert!(events.iter().any(|e| e.token == 43 && e.readable));
        let mut d = d;
        let mut buf = [0u8; 8];
        assert_eq!(d.read(&mut buf).expect("read"), 1);
        assert_eq!(d.read(&mut buf).expect("read eof"), 0);
    }
}
