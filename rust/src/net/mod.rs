//! Concurrent network serving subsystem (`DESIGN.md` §8).
//!
//! `icr serve` historically spoke JSONL over stdin/stdout — one client,
//! one request in flight. This module turns the coordinator into a real
//! server with three layers:
//!
//! - **[`transport`]** — `--listen tcp:HOST:PORT | unix:PATH | stdio`
//!   ([`ListenAddr`]): a [`NetServer`] accept loop hosting many
//!   concurrent connections, each speaking the existing JSONL protocol
//!   v1/v2 unchanged over the socket, with a `--max-connections` cap and
//!   graceful shutdown (SIGINT drains in-flight requests, refuses new
//!   ones).
//! - **[`event_loop`]** — the default connection host (`--io-mode event`,
//!   `DESIGN.md` §11): ONE thread owns every accepted socket behind an
//!   epoll/poll readiness loop ([`poller`]), framing JSONL lines from
//!   per-connection read buffers, submitting into the coordinator's
//!   shared batcher, and draining per-connection write buffers on
//!   writability — so `icr serve` holds thousands of mostly-idle
//!   connections without per-connection threads or poll wakeups.
//! - **[`session`]** — the legacy `--io-mode threads` host kept for A/B
//!   benchmarking: one reader + one writer thread per connection, the
//!   reader submitting frames into the same shared batcher (so requests
//!   from *different* connections coalesce into the same panel batches),
//!   the writer demultiplexing replies back in submission order. Both
//!   hosts share the contracts: queue-full backpressure answers with a
//!   typed v2 `overloaded` error frame in submission order, and idle
//!   connections time out.
//! - **[`router`]** — replica sets over the model registry
//!   (`--replicas gp=native:3` builds N identical entries sharing one
//!   [`crate::parallel::WorkerPool`]) with pluggable routing policies
//!   ([`RoutePolicy`]: round-robin, least-outstanding, seed-affinity).
//!
//! The wire protocol is byte-identical across transports *and* io modes;
//! `stdio` remains the default and is served by the inline loop in
//! `main.rs`.

#[cfg(unix)]
pub mod event_loop;
#[cfg(unix)]
pub(crate) mod poller;
pub mod router;
pub mod session;
pub mod transport;

pub use router::{BreakerConfig, BreakerState, MemberState, ReplicaSet, RoutePolicy, Router};
pub use transport::{bind_metrics, install_sigint_handler, sigint_requested, NetServer};

use std::fmt;
use std::path::PathBuf;

/// Transports `icr serve --listen` can bind (advertised by
/// `icr --version` and the `stats` document).
pub const TRANSPORTS: [&str; 3] = ["stdio", "tcp", "unix"];

/// How `icr serve` hosts socket connections (`--io-mode`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum IoMode {
    /// One epoll/poll readiness loop owns every connection
    /// (`DESIGN.md` §11) — the default on unix.
    #[default]
    Event,
    /// Legacy two-threads-per-connection sessions (`DESIGN.md` §8),
    /// kept as the `connections_scaling` bench baseline and as the
    /// fallback where no poller exists. Stdio always serves blocking,
    /// regardless of this mode.
    Threads,
}

impl IoMode {
    /// Parse `event` | `threads`.
    pub fn parse(s: &str) -> Result<IoMode, String> {
        match s {
            "event" => Ok(IoMode::Event),
            "threads" => Ok(IoMode::Threads),
            _ => Err(format!("io mode {s:?} must be event | threads")),
        }
    }

    /// Canonical flag spelling.
    pub fn name(self) -> &'static str {
        match self {
            IoMode::Event => "event",
            IoMode::Threads => "threads",
        }
    }
}

/// Where `icr serve` listens for clients.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub enum ListenAddr {
    /// JSONL over stdin/stdout — the legacy single-client loop, and still
    /// the default.
    #[default]
    Stdio,
    /// TCP socket, `host:port` (port `0` picks an ephemeral port).
    Tcp(String),
    /// Unix domain socket at a filesystem path.
    Unix(PathBuf),
}

impl ListenAddr {
    /// Parse `stdio`, `tcp:HOST:PORT` or `unix:PATH`.
    pub fn parse(s: &str) -> Result<ListenAddr, String> {
        if s == "stdio" {
            return Ok(ListenAddr::Stdio);
        }
        if let Some(rest) = s.strip_prefix("tcp:") {
            if rest.is_empty() {
                return Err(format!("listen address {s:?} is missing HOST:PORT"));
            }
            return Ok(ListenAddr::Tcp(rest.to_string()));
        }
        if let Some(rest) = s.strip_prefix("unix:") {
            if rest.is_empty() {
                return Err(format!("listen address {s:?} is missing a socket path"));
            }
            return Ok(ListenAddr::Unix(PathBuf::from(rest)));
        }
        Err(format!(
            "listen address {s:?} must be stdio | tcp:HOST:PORT | unix:PATH"
        ))
    }

    /// Transport name (`stdio` | `tcp` | `unix`).
    pub fn transport(&self) -> &'static str {
        match self {
            ListenAddr::Stdio => "stdio",
            ListenAddr::Tcp(_) => "tcp",
            ListenAddr::Unix(_) => "unix",
        }
    }
}

impl fmt::Display for ListenAddr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ListenAddr::Stdio => write!(f, "stdio"),
            ListenAddr::Tcp(hp) => write!(f, "tcp:{hp}"),
            ListenAddr::Unix(p) => write!(f, "unix:{}", p.display()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn listen_addr_parse_roundtrip() {
        for s in ["stdio", "tcp:127.0.0.1:7777", "unix:/tmp/icr.sock"] {
            let addr = ListenAddr::parse(s).unwrap();
            assert_eq!(addr.to_string(), s);
        }
        assert_eq!(ListenAddr::parse("stdio").unwrap().transport(), "stdio");
        assert_eq!(ListenAddr::parse("tcp:0.0.0.0:0").unwrap().transport(), "tcp");
        assert_eq!(ListenAddr::parse("unix:/x").unwrap().transport(), "unix");
        assert_eq!(ListenAddr::default(), ListenAddr::Stdio);
    }

    #[test]
    fn listen_addr_rejects_malformed() {
        for s in ["tcp:", "unix:", "http:localhost", "7777"] {
            assert!(ListenAddr::parse(s).is_err(), "{s} should not parse");
        }
    }

    #[test]
    fn transports_are_advertised_in_order() {
        assert_eq!(TRANSPORTS, ["stdio", "tcp", "unix"]);
    }

    #[test]
    fn io_mode_parse_roundtrip() {
        assert_eq!(IoMode::parse("event").unwrap(), IoMode::Event);
        assert_eq!(IoMode::parse("threads").unwrap(), IoMode::Threads);
        for mode in [IoMode::Event, IoMode::Threads] {
            assert_eq!(IoMode::parse(mode.name()).unwrap(), mode);
        }
        assert_eq!(IoMode::default(), IoMode::Event);
        assert!(IoMode::parse("fibers").is_err());
    }
}
