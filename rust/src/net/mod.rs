//! Concurrent network serving subsystem (`DESIGN.md` §8).
//!
//! `icr serve` historically spoke JSONL over stdin/stdout — one client,
//! one request in flight. This module turns the coordinator into a real
//! server with three layers:
//!
//! - **[`transport`]** — `--listen tcp:HOST:PORT | unix:PATH | stdio`
//!   ([`ListenAddr`]): a [`NetServer`] accept loop hosting many
//!   concurrent connections, each speaking the existing JSONL protocol
//!   v1/v2 unchanged over the socket, with a `--max-connections` cap and
//!   graceful shutdown (SIGINT drains in-flight requests, refuses new
//!   ones).
//! - **[`session`]** — one session per connection: a reader thread parses
//!   frames and submits them into the coordinator's shared batcher (so
//!   requests from *different* connections coalesce into the same panel
//!   batches), a writer thread demultiplexes replies back in submission
//!   order. Queue-full backpressure answers with a typed v2 `overloaded`
//!   error frame; idle connections time out.
//! - **[`router`]** — replica sets over the model registry
//!   (`--replicas gp=native:3` builds N identical entries sharing one
//!   [`crate::parallel::WorkerPool`]) with pluggable routing policies
//!   ([`RoutePolicy`]: round-robin, least-outstanding, seed-affinity).
//!
//! The wire protocol is byte-identical across transports; `stdio` remains
//! the default and is served by the inline loop in `main.rs`.

pub mod router;
pub mod session;
pub mod transport;

pub use router::{MemberState, ReplicaSet, RoutePolicy, Router};
pub use transport::{install_sigint_handler, sigint_requested, NetServer};

use std::fmt;
use std::path::PathBuf;

/// Transports `icr serve --listen` can bind (advertised by
/// `icr --version` and the `stats` document).
pub const TRANSPORTS: [&str; 3] = ["stdio", "tcp", "unix"];

/// Where `icr serve` listens for clients.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub enum ListenAddr {
    /// JSONL over stdin/stdout — the legacy single-client loop, and still
    /// the default.
    #[default]
    Stdio,
    /// TCP socket, `host:port` (port `0` picks an ephemeral port).
    Tcp(String),
    /// Unix domain socket at a filesystem path.
    Unix(PathBuf),
}

impl ListenAddr {
    /// Parse `stdio`, `tcp:HOST:PORT` or `unix:PATH`.
    pub fn parse(s: &str) -> Result<ListenAddr, String> {
        if s == "stdio" {
            return Ok(ListenAddr::Stdio);
        }
        if let Some(rest) = s.strip_prefix("tcp:") {
            if rest.is_empty() {
                return Err(format!("listen address {s:?} is missing HOST:PORT"));
            }
            return Ok(ListenAddr::Tcp(rest.to_string()));
        }
        if let Some(rest) = s.strip_prefix("unix:") {
            if rest.is_empty() {
                return Err(format!("listen address {s:?} is missing a socket path"));
            }
            return Ok(ListenAddr::Unix(PathBuf::from(rest)));
        }
        Err(format!(
            "listen address {s:?} must be stdio | tcp:HOST:PORT | unix:PATH"
        ))
    }

    /// Transport name (`stdio` | `tcp` | `unix`).
    pub fn transport(&self) -> &'static str {
        match self {
            ListenAddr::Stdio => "stdio",
            ListenAddr::Tcp(_) => "tcp",
            ListenAddr::Unix(_) => "unix",
        }
    }
}

impl fmt::Display for ListenAddr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ListenAddr::Stdio => write!(f, "stdio"),
            ListenAddr::Tcp(hp) => write!(f, "tcp:{hp}"),
            ListenAddr::Unix(p) => write!(f, "unix:{}", p.display()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn listen_addr_parse_roundtrip() {
        for s in ["stdio", "tcp:127.0.0.1:7777", "unix:/tmp/icr.sock"] {
            let addr = ListenAddr::parse(s).unwrap();
            assert_eq!(addr.to_string(), s);
        }
        assert_eq!(ListenAddr::parse("stdio").unwrap().transport(), "stdio");
        assert_eq!(ListenAddr::parse("tcp:0.0.0.0:0").unwrap().transport(), "tcp");
        assert_eq!(ListenAddr::parse("unix:/x").unwrap().transport(), "unix");
        assert_eq!(ListenAddr::default(), ListenAddr::Stdio);
    }

    #[test]
    fn listen_addr_rejects_malformed() {
        for s in ["tcp:", "unix:", "http:localhost", "7777"] {
            assert!(ListenAddr::parse(s).is_err(), "{s} should not parse");
        }
    }

    #[test]
    fn transports_are_advertised_in_order() {
        assert_eq!(TRANSPORTS, ["stdio", "tcp", "unix"]);
    }
}
