//! Per-connection session: JSONL framing over a socket, pipelined
//! request submission, in-order reply demultiplexing. This is the
//! legacy `--io-mode threads` host (the default is the event loop in
//! [`super::event_loop`], `DESIGN.md` §11, which serves the identical
//! wire contract without per-connection threads).
//!
//! Each connection gets two threads. The **reader** frames lines off the
//! socket (preserving partial lines across read timeouts), parses them
//! with the same [`protocol`] codec the stdio loop uses, and submits
//! every request straight into the coordinator's shared queue — which is
//! what makes requests from *different* connections coalesce into the
//! same panel batches. The **writer** drains a session-local FIFO of
//! pending replies, blocking on each in submission order, so every
//! client sees its responses in the order it sent the requests while
//! other sessions proceed independently (fair per-session demux, no
//! cross-session head-of-line blocking).
//!
//! Backpressure: a full bounded coordinator queue answers the submit
//! immediately with a typed [`IcrError::Overloaded`], which flows to the
//! client as a v2 `overloaded` error frame in-order like any reply.
//! Lifecycle: EOF, an idle timeout with nothing in flight, a dead peer,
//! or a server drain all end the reader; the writer then flushes what
//! was already submitted and the session hangs up.

use std::io::{self, BufWriter, Read, Write};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use crate::coordinator::{protocol, Coordinator, ReplySlot, Response};
use crate::error::IcrError;
use crate::metrics::Registry;

use super::transport::{sigint_requested, Conn};

/// Everything a session needs from the server.
pub(crate) struct SessionCtx {
    pub coord: Arc<Coordinator>,
    pub shutdown: Arc<AtomicBool>,
    /// Zero disables the idle timeout.
    pub idle_timeout: Duration,
    /// Reader poll granularity (`--io-poll-ms`): how often an idle
    /// blocking reader re-checks the drain flag and the idle deadline.
    /// Only the blocking paths poll — the event loop (`DESIGN.md` §11)
    /// sleeps on readiness instead.
    pub io_poll: Duration,
    pub transport: Registry,
    /// Server-wide open-connection count (decremented on session exit).
    pub open: Arc<AtomicUsize>,
}

impl SessionCtx {
    fn draining(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst) || sigint_requested()
    }
}

/// One queued reply, emitted by the writer in submission order.
enum Outgoing {
    /// Answered at parse time (malformed frame) — no coordinator round
    /// trip, but still serialized in-order behind earlier replies.
    Ready { version: u64, id: u64, error: IcrError },
    /// In flight at the coordinator.
    Pending {
        version: u64,
        id: u64,
        /// Raw coordinator request id — the span-tree echo stash key
        /// (`id` echoes the client's correlation id when supplied).
        req_id: u64,
        /// Frame carried a trace context: pop the echo at encode time.
        want_trace: bool,
        model: String,
        rx: mpsc::Receiver<Result<Response, IcrError>>,
    },
}

/// Serve one connection to completion. Consumes the connection; returns
/// after both halves have hung up.
pub(crate) fn run(conn: Conn, ctx: SessionCtx) {
    let outstanding = Arc::new(AtomicUsize::new(0));
    let peer_gone = Arc::new(AtomicBool::new(false));
    let (tx, rx) = mpsc::channel::<Outgoing>();

    let writer = match conn.try_clone() {
        Ok(write_half) => {
            let coord = ctx.coord.clone();
            let transport = ctx.transport.clone();
            let outstanding = outstanding.clone();
            let peer_gone = peer_gone.clone();
            std::thread::Builder::new()
                .name("icr-session-writer".into())
                .spawn(move || {
                    writer_loop(write_half, rx, coord, transport, outstanding, peer_gone)
                })
                .ok()
        }
        Err(_) => None,
    };

    if writer.is_some() {
        reader_loop(conn, &ctx, tx, &outstanding, &peer_gone);
    } else {
        drop(tx);
    }
    if let Some(w) = writer {
        let _ = w.join();
    }
    ctx.open.fetch_sub(1, Ordering::SeqCst);
    ctx.transport.gauge("connections_open").dec();
}

fn reader_loop(
    conn: Conn,
    ctx: &SessionCtx,
    tx: mpsc::Sender<Outgoing>,
    outstanding: &AtomicUsize,
    peer_gone: &AtomicBool,
) {
    let _ = conn.set_read_timeout(Some(ctx.io_poll));
    let mut lines = LineReader::new(conn);
    let mut last_active = Instant::now();
    let mut last_buffered = 0usize;
    loop {
        if ctx.draining() || peer_gone.load(Ordering::SeqCst) {
            break;
        }
        match lines.next_line() {
            Ok(Some(line)) => {
                last_active = Instant::now();
                last_buffered = lines.buffered();
                if line.trim().is_empty() {
                    continue;
                }
                ctx.transport.counter("frames_in").inc();
                let msg = match protocol::parse_request(&line) {
                    Ok(frame) => {
                        let want_trace = frame.wants_trace();
                        let (slot, reply) = ReplySlot::channel();
                        let id = ctx.coord.submit_sink_traced(
                            frame.model.as_deref(),
                            frame.request,
                            slot,
                            frame.trace.as_ref(),
                        );
                        let model = frame
                            .model
                            .unwrap_or_else(|| ctx.coord.default_model().to_string());
                        Outgoing::Pending {
                            version: frame.version,
                            id: frame.client_id.unwrap_or(id),
                            req_id: id,
                            want_trace,
                            model,
                            rx: reply,
                        }
                    }
                    Err(e) => {
                        let (version, id) = protocol::frame_error_context(&line);
                        Outgoing::Ready { version, id: id.unwrap_or(0), error: e }
                    }
                };
                outstanding.fetch_add(1, Ordering::SeqCst);
                if tx.send(msg).is_err() {
                    break;
                }
            }
            Ok(None) => break, // EOF: client finished sending.
            Err(e) if is_timeout(&e) => {
                // Partial-frame bytes count as activity: a slow client
                // mid-upload must never be cut off as idle.
                if lines.buffered() != last_buffered {
                    last_buffered = lines.buffered();
                    last_active = Instant::now();
                }
                if !ctx.idle_timeout.is_zero()
                    && outstanding.load(Ordering::SeqCst) == 0
                    && last_active.elapsed() >= ctx.idle_timeout
                {
                    ctx.transport.counter("connections_idle_closed").inc();
                    break;
                }
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(_) => break,
        }
    }
    // Dropping tx lets the writer drain what was submitted and exit.
}

fn writer_loop(
    conn: Conn,
    rx: mpsc::Receiver<Outgoing>,
    coord: Arc<Coordinator>,
    transport: Registry,
    outstanding: Arc<AtomicUsize>,
    peer_gone: Arc<AtomicBool>,
) {
    let mut out = BufWriter::new(conn);
    for msg in rx {
        let frame = match msg {
            Outgoing::Ready { version, id, error } => {
                protocol::encode_response(version, id, None, &Err(error), None)
            }
            Outgoing::Pending { version, id, req_id, want_trace, model, rx } => {
                let result = rx.recv().unwrap_or_else(|_| {
                    Err(IcrError::Internal("coordinator dropped the reply channel".into()))
                });
                // The coordinator stashes the span-tree echo before it
                // sends the reply, so the pop after `recv` always
                // observes it for explicitly traced requests.
                let trace = if want_trace { coord.take_trace_echo(req_id) } else { None };
                coord.with_phase("request;serialize_reply", || {
                    protocol::encode_response_traced(version, id, Some(&model), &result, trace)
                })
            }
        };
        outstanding.fetch_sub(1, Ordering::SeqCst);
        // Counted before the write so the counter is always current by
        // the time a client observes the reply.
        transport.counter("frames_out").inc();
        if writeln!(out, "{}", frame.to_json()).and_then(|_| out.flush()).is_err() {
            // Client hung up; tell the reader to stop submitting.
            peer_gone.store(true, Ordering::SeqCst);
            break;
        }
    }
}

fn is_timeout(e: &io::Error) -> bool {
    matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut)
}

/// Newline framing over a read-timeout socket. `BufRead::read_line`
/// discards partially-read bytes when the underlying read times out;
/// this reader keeps them buffered so a frame can straddle any number of
/// poll timeouts without loss.
struct LineReader {
    conn: Conn,
    pending: Vec<u8>,
    eof: bool,
}

impl LineReader {
    fn new(conn: Conn) -> LineReader {
        LineReader { conn, pending: Vec::new(), eof: false }
    }

    /// Bytes of a not-yet-complete frame currently buffered (the idle
    /// check treats growth here as client activity).
    fn buffered(&self) -> usize {
        self.pending.len()
    }

    /// Next complete line without its terminator; `Ok(None)` at EOF. A
    /// timeout surfaces as `Err(WouldBlock | TimedOut)` with all
    /// partial-line bytes retained.
    fn next_line(&mut self) -> io::Result<Option<String>> {
        loop {
            if let Some(pos) = self.pending.iter().position(|&b| b == b'\n') {
                let rest = self.pending.split_off(pos + 1);
                let mut line = std::mem::replace(&mut self.pending, rest);
                line.pop();
                if line.last() == Some(&b'\r') {
                    line.pop();
                }
                return Ok(Some(String::from_utf8_lossy(&line).into_owned()));
            }
            if self.eof {
                if self.pending.is_empty() {
                    return Ok(None);
                }
                let line = std::mem::take(&mut self.pending);
                return Ok(Some(String::from_utf8_lossy(&line).into_owned()));
            }
            let mut buf = [0u8; 4096];
            match self.conn.read(&mut buf) {
                Ok(0) => self.eof = true,
                Ok(n) => self.pending.extend_from_slice(&buf[..n]),
                Err(e) => return Err(e),
            }
        }
    }
}
