//! Fig. 4 bench: forward-pass time, ICR vs KISS-GP, across N.
//!
//! The paper times one forward pass per method: ICR = one application of
//! `√K_ICR`; KISS-GP = 40 CG iterations (inverse) + 10×15 stochastic
//! Lanczos (log-det). Run `cargo bench --bench fig4_forward`; full-size
//! sweeps (and the PJRT lane) live in `icr experiment fig4`.

use icr::bench::Runner;
use icr::experiments::{paper, paper_engine};
use icr::kernels::Matern;
use icr::kissgp::{KissGp, KissGpConfig};
use icr::rng::Rng;

fn main() {
    let mut runner = Runner::new();
    runner.header("Fig. 4 — forward pass: ICR apply vs KISS-GP CG+Lanczos (native)");
    let mut rng = Rng::new(77);
    let kernel = Matern::nu32(paper::RHO, 1.0);

    for &target in &[256usize, 1024, 4096, 16384] {
        // ICR: the §5.1 optimum (5,4) and the classical (3,2).
        for &(c, f) in &[(5usize, 4usize), (3, 2)] {
            let engine = paper_engine(c, f, target).expect("engine");
            let xi = rng.standard_normal_vec(engine.total_dof());
            let mut sink = 0.0;
            runner.bench(&format!("icr_c{c}f{f}/apply_sqrt/n{}", engine.n_points()), || {
                sink += engine.apply_sqrt(&xi)[0];
            });
            std::hint::black_box(sink);
        }
        // KISS-GP on the same modeled points.
        let engine = paper_engine(3, 2, target).expect("engine");
        let points = engine.domain_points().to_vec();
        let n = points.len();
        let kiss = KissGp::build(&kernel, &points, KissGpConfig::paper_speed(n)).expect("kiss");
        let y = rng.standard_normal_vec(n);
        let mut probe_rng = Rng::new(5);
        let mut sink = 0.0;
        runner.bench(&format!("kissgp/forward_cg40_slq/n{n}"), || {
            let (x, logdet, _) = kiss.forward(&y, &mut probe_rng);
            sink += x[0] + logdet;
        });
        std::hint::black_box(sink);
    }

    runner.dump_jsonl("results/bench_fig4.jsonl").ok();
    // Headline check mirrored from the paper: ICR ≥ several × faster.
    let icr_med: Vec<f64> = runner
        .results
        .iter()
        .filter(|r| r.name.starts_with("icr_c5f4"))
        .map(|r| r.median_ns)
        .collect();
    let kiss_med: Vec<f64> =
        runner.results.iter().filter(|r| r.name.starts_with("kissgp")).map(|r| r.median_ns).collect();
    for (i, (icr_t, kiss_t)) in icr_med.iter().zip(&kiss_med).enumerate() {
        println!("speedup[{i}] = {:.1}x (KISS / ICR(5,4))", kiss_t / icr_t);
    }
}
