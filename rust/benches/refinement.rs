//! Refinement benchmarks: the Eq. 13 O(N) scaling claim and the §4.4
//! construction-cost claim, plus stationary-vs-charted ablation.
//!
//! - `apply/*`: per-point apply cost must stay flat as N doubles (O(N)).
//! - `construct/*`: refinement-matrix construction is O(N) with a
//!   constant ∝ max(n_csz, n_fsz)³ (paper §4.4) and is amortized once per
//!   kernel-hyperparameter update.
//! - `ablation/*`: the broadcast (stationary) fast path vs per-window
//!   matrices on the same geometry — the §4.3 symmetry optimization.

use icr::bench::Runner;
use icr::chart::{Chart, IdentityChart};
use icr::experiments::paper_engine;
use icr::icr::{IcrEngine, RefinementParams};
use icr::kernels::Matern;
use icr::rng::Rng;

struct OpaqueIdentity;
impl Chart for OpaqueIdentity {
    fn to_domain(&self, u: f64) -> f64 {
        u
    }
    fn to_grid(&self, x: f64) -> f64 {
        x
    }
    fn name(&self) -> &'static str {
        "opaque-identity"
    }
}

fn main() {
    let mut runner = Runner::new();
    let mut rng = Rng::new(3);

    runner.header("Eq. 13 — O(N) apply scaling (charted log grid, (5,4))");
    let mut per_point = Vec::new();
    for &target in &[512usize, 2048, 8192, 32768] {
        let engine = paper_engine(5, 4, target).expect("engine");
        let xi = rng.standard_normal_vec(engine.total_dof());
        let mut sink = 0.0;
        let r = runner.bench(&format!("apply/charted_c5f4/n{}", engine.n_points()), || {
            sink += engine.apply_sqrt(&xi)[0];
        });
        if let Some(r) = r {
            per_point.push((engine.n_points(), r.median_ns / engine.n_points() as f64));
        }
        std::hint::black_box(sink);
    }
    for (n, ns) in &per_point {
        println!("  per-point cost at N={n}: {ns:.1} ns");
    }

    runner.header("§4.4 — construction cost (matrices per hyperparameter update)");
    for &target in &[512usize, 2048, 8192] {
        let params = RefinementParams::for_target(5, 4, 5, target).expect("params");
        let chart = icr::experiments::paper_chart(params, 0.02, 1.0);
        let kernel = Matern::nu32(1.0, 1.0);
        let mut sink = 0;
        runner.bench(&format!("construct/charted_c5f4/n{}", params.final_size()), || {
            let e = IcrEngine::build(&kernel, &chart, params).expect("build");
            sink += e.n_points();
        });
        std::hint::black_box(sink);
    }

    runner.header("§4.3 ablation — stationary broadcast vs per-window matrices");
    let params = RefinementParams::for_target(5, 4, 5, 4096).expect("params");
    let kernel = Matern::nu32(64.0, 1.0);
    let fast = IcrEngine::build(&kernel, &IdentityChart::unit(), params).expect("fast");
    let slow = IcrEngine::build(&kernel, &OpaqueIdentity, params).expect("slow");
    assert!(fast.is_stationary() && !slow.is_stationary());
    let xi = rng.standard_normal_vec(fast.total_dof());
    let mut sink = 0.0;
    runner.bench("ablation/apply_stationary/n4096", || {
        sink += fast.apply_sqrt(&xi)[0];
    });
    runner.bench("ablation/apply_per_window/n4096", || {
        sink += slow.apply_sqrt(&xi)[0];
    });
    std::hint::black_box(sink);
    let mut sink2 = 0;
    runner.bench("ablation/construct_stationary/n4096", || {
        sink2 += IcrEngine::build(&kernel, &IdentityChart::unit(), params).unwrap().n_points();
    });
    runner.bench("ablation/construct_per_window/n4096", || {
        sink2 += IcrEngine::build(&kernel, &OpaqueIdentity, params).unwrap().n_points();
    });
    std::hint::black_box(sink2);

    runner.header("adjoint — apply_sqrt vs apply_sqrt_transpose (backprop cost, §1)");
    let engine = paper_engine(5, 4, 4096).expect("engine");
    let xi = rng.standard_normal_vec(engine.total_dof());
    let g = rng.standard_normal_vec(engine.n_points());
    let mut sink = 0.0;
    runner.bench("adjoint/forward/n4096", || {
        sink += engine.apply_sqrt(&xi)[0];
    });
    runner.bench("adjoint/transpose/n4096", || {
        sink += engine.apply_sqrt_transpose(&g)[0];
    });
    std::hint::black_box(sink);

    runner.dump_jsonl("results/bench_refinement.jsonl").ok();
}
