//! Serve-path throughput bench: a real Unix-socket server under
//! synchronous JSONL clients, swept over connections × batch × model
//! family — plus a **cluster** case (front door routing a mixed
//! local+remote replica set across a real tcp backend) and a
//! **latency-budget** summary comparing client-observed serve p50/p99
//! against the raw panel-apply floor of the same served model
//! (ROADMAP serving item; `BENCH_apply.json` carries the deep-geometry
//! apply trajectory, the floor here is measured inline on the serve
//! model so the ratio is apples-to-apples). Run with `--json` to write
//! `BENCH_serve.json` (overridable as `--json=path`), embedding the
//! same hardware metadata block as `BENCH_apply.json`:
//!
//! ```text
//! cargo bench --bench serve_throughput -- --json
//! ```
//!
//! Knobs: `ICR_BENCH_SERVE_REQS` (requests per client, default 200),
//! `ICR_BENCH_SERVE_SCALE_CONNS` / `ICR_BENCH_SERVE_SCALE_REQS` (ceiling
//! and per-driver requests of the `connections_scaling` sweep, defaults
//! 2048 / 50) — the sweep pits the legacy threads-per-session host
//! against the event loop at identical driver load and pushes the event
//! loop to a connection count no thread-pair host reasonably reaches.

use std::io::{BufRead, BufReader, Write};
use std::os::unix::net::UnixStream;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use icr::bench::hardware_json;
use icr::config::{Backend, MemberSpec, ModelConfig, ReplicaSpec, ServerConfig};
use icr::coordinator::Coordinator;
use icr::json::{self, Value};
use icr::model::{GpModel, ModelBuilder};
use icr::net::{IoMode, ListenAddr, NetServer};
use icr::rng::Rng;

struct CaseResult {
    name: String,
    requests: usize,
    requests_per_sec: f64,
    p50_us: f64,
    p99_us: f64,
    mean_batch: f64,
}

impl CaseResult {
    fn to_json(&self) -> Value {
        json::obj(vec![
            ("name", json::s(&self.name)),
            ("requests", json::num(self.requests as f64)),
            ("requests_per_sec", json::num(self.requests_per_sec)),
            ("p50_us", json::num(self.p50_us)),
            ("p99_us", json::num(self.p99_us)),
            ("mean_batch", json::num(self.mean_batch)),
        ])
    }
}

/// Exact sample quantile over the raw client-observed latencies. Note
/// the asymmetry with the server's own telemetry: the `p50_us`/`p99_us`
/// a live server reports (`stats` document, and what Prometheus derives
/// from the `_bucket` series behind `--metrics-listen`) come from log₂
/// histogram buckets and are geometric-midpoint *estimates*, accurate
/// only to within a factor of √2 ≈ 1.41 either way. Comparing
/// `BENCH_serve.json` quantiles against server-reported ones must
/// budget for that bound; agreement tighter than √2 is coincidence.
fn quantile(sorted_us: &[f64], q: f64) -> f64 {
    if sorted_us.is_empty() {
        return 0.0;
    }
    let idx = ((sorted_us.len() as f64 - 1.0) * q).round() as usize;
    sorted_us[idx.min(sorted_us.len() - 1)]
}

/// Drive `conns` synchronous clients × `reqs` sample requests against a
/// running front socket; returns sorted client-observed latencies (µs).
fn drive_clients(
    sock: &std::path::Path,
    model: Option<&str>,
    conns: usize,
    batch: usize,
    reqs: usize,
) -> Vec<f64> {
    let mut all_lat_us: Vec<f64> = Vec::with_capacity(conns * reqs);
    std::thread::scope(|sc| {
        let mut threads = Vec::new();
        for c in 0..conns {
            threads.push(sc.spawn(move || {
                let stream = UnixStream::connect(sock).expect("connect");
                let mut reader = BufReader::new(stream.try_clone().expect("clone"));
                let mut writer = stream;
                let mut lat = Vec::with_capacity(reqs);
                let mut line = String::new();
                for i in 0..reqs {
                    let seed = (c * reqs + i) as u64;
                    let model_field = match model {
                        Some(m) => format!(r#""model": "{m}", "#),
                        None => String::new(),
                    };
                    let t = Instant::now();
                    writeln!(
                        writer,
                        r#"{{"v": 2, {model_field}"op": "sample", "id": {i}, "count": {batch}, "seed": {seed}}}"#
                    )
                    .expect("send");
                    writer.flush().expect("flush");
                    line.clear();
                    let n = reader.read_line(&mut line).expect("recv");
                    assert!(n > 0, "server hung up");
                    lat.push(t.elapsed().as_secs_f64() * 1e6);
                    assert!(line.contains("\"ok\":true"), "request failed: {line}");
                }
                lat
            }));
        }
        for t in threads {
            all_lat_us.extend(t.join().expect("client thread"));
        }
    });
    all_lat_us.sort_by(|a, b| a.partial_cmp(b).unwrap());
    all_lat_us
}

fn finish_case(
    name: String,
    coord: &Coordinator,
    total: usize,
    wall: f64,
    sorted_lat_us: &[f64],
) -> CaseResult {
    let applies = coord.metrics().counter("applies_executed").get() as f64;
    let batches = coord.metrics().histogram("batch_applies").count() as f64;
    CaseResult {
        name,
        requests: total,
        requests_per_sec: total as f64 / wall,
        p50_us: quantile(sorted_lat_us, 0.50),
        p99_us: quantile(sorted_lat_us, 0.99),
        mean_batch: if batches > 0.0 { applies / batches } else { 0.0 },
    }
}

fn run_case(family: &str, backend: Backend, conns: usize, batch: usize, reqs: usize) -> CaseResult {
    let sock = std::env::temp_dir().join(format!(
        "icr_bench_{}_{family}_{conns}_{batch}.sock",
        std::process::id()
    ));
    let cfg = ServerConfig {
        model: ModelConfig::default(), // the paper's N ≈ 200 geometry
        backend,
        workers: 2,
        max_batch: 16,
        max_wait_us: 200,
        idle_timeout_ms: 0,
        listen: ListenAddr::Unix(sock.clone()),
        ..ServerConfig::default()
    };
    let coord = Arc::new(Coordinator::start(cfg.clone()).expect("coordinator"));
    let server = NetServer::bind(&cfg, coord.clone()).expect("bind");
    let stop = server.shutdown_handle();
    let handle = std::thread::spawn(move || server.run());

    let t0 = Instant::now();
    let lat = drive_clients(&sock, None, conns, batch, reqs);
    let wall = t0.elapsed().as_secs_f64();

    let result = finish_case(format!("serve/{family}/c{conns}/b{batch}"), &coord, conns * reqs, wall, &lat);
    stop.store(true, Ordering::SeqCst);
    handle.join().expect("server thread").expect("server run");
    if let Ok(coord) = Arc::try_unwrap(coord) {
        coord.shutdown();
    }
    std::fs::remove_file(&sock).ok();
    result
}

/// Connections-scaling case: `conns` live sockets against one server in
/// the given `--io-mode`, with at most 64 of them actively driven (the
/// scaling axis is how many live connections the host sustains, not how
/// many the driver saturates at once — the rest sit connected and idle,
/// which is exactly what costs a thread pair per socket in threads mode
/// and nothing but an fd in event mode).
fn run_scaling_case(mode: IoMode, conns: usize, reqs: usize) -> CaseResult {
    let sock = std::env::temp_dir().join(format!(
        "icr_bench_scale_{}_{}_{conns}.sock",
        std::process::id(),
        mode.name()
    ));
    let cfg = ServerConfig {
        model: ModelConfig::default(),
        workers: 2,
        max_batch: 16,
        max_wait_us: 200,
        idle_timeout_ms: 0,
        max_connections: conns + 8,
        io_mode: mode,
        listen: ListenAddr::Unix(sock.clone()),
        ..ServerConfig::default()
    };
    let coord = Arc::new(Coordinator::start(cfg.clone()).expect("coordinator"));
    let server = NetServer::bind(&cfg, coord.clone()).expect("bind");
    let stop = server.shutdown_handle();
    let handle = std::thread::spawn(move || server.run());

    let active = conns.min(64);
    let mut idle = Vec::with_capacity(conns - active);
    for _ in 0..conns - active {
        // A full accept backlog surfaces as a transient connect error on
        // unix sockets; back off and retry instead of failing the case.
        let mut tries = 0u32;
        let s = loop {
            match UnixStream::connect(&sock) {
                Ok(s) => break s,
                Err(e) if tries < 2000 => {
                    let _ = e;
                    tries += 1;
                    std::thread::sleep(Duration::from_millis(1));
                }
                Err(e) => panic!("idle connect: {e}"),
            }
        };
        idle.push(s);
    }
    let t0 = Instant::now();
    let lat = drive_clients(&sock, None, active, 1, reqs);
    let wall = t0.elapsed().as_secs_f64();
    drop(idle);

    let result = finish_case(
        format!("serve/scaling/{}/c{conns}", mode.name()),
        &coord,
        active * reqs,
        wall,
        &lat,
    );
    stop.store(true, Ordering::SeqCst);
    handle.join().expect("server thread").expect("server run");
    if let Ok(coord) = Arc::try_unwrap(coord) {
        coord.shutdown();
    }
    std::fs::remove_file(&sock).ok();
    result
}

/// Cluster case: a tcp backend node plus a front door whose `gp` set
/// mixes one local native member with the remote backend; clients
/// address the logical name, so requests cross the process boundary for
/// the seeds rendezvous pins to the remote member.
fn run_cluster_case(conns: usize, batch: usize, reqs: usize) -> CaseResult {
    let backend_cfg = ServerConfig {
        model: ModelConfig::default(),
        workers: 2,
        max_batch: 16,
        max_wait_us: 200,
        idle_timeout_ms: 0,
        listen: ListenAddr::Tcp("127.0.0.1:0".into()),
        ..ServerConfig::default()
    };
    let backend = Arc::new(Coordinator::start(backend_cfg.clone()).expect("backend coordinator"));
    let backend_server = NetServer::bind(&backend_cfg, backend.clone()).expect("bind backend");
    let backend_addr = backend_server.local_addr().to_string(); // "tcp:IP:PORT"
    let backend_stop = backend_server.shutdown_handle();
    let backend_handle = std::thread::spawn(move || backend_server.run());

    let sock = std::env::temp_dir()
        .join(format!("icr_bench_cluster_{}_{conns}_{batch}.sock", std::process::id()));
    let cfg = ServerConfig {
        model: ModelConfig::default(),
        workers: 2,
        max_batch: 16,
        max_wait_us: 200,
        idle_timeout_ms: 0,
        listen: ListenAddr::Unix(sock.clone()),
        replicas: vec![ReplicaSpec::new(
            "gp",
            vec![
                MemberSpec::local(Backend::Native),
                MemberSpec::remote(&backend_addr).expect("remote member"),
            ],
        )
        .expect("replica spec")],
        ..ServerConfig::default()
    };
    let front = Arc::new(Coordinator::start(cfg.clone()).expect("front door"));
    let server = NetServer::bind(&cfg, front.clone()).expect("bind front");
    let stop = server.shutdown_handle();
    let handle = std::thread::spawn(move || server.run());

    let t0 = Instant::now();
    let lat = drive_clients(&sock, Some("gp"), conns, batch, reqs);
    let wall = t0.elapsed().as_secs_f64();

    let result =
        finish_case(format!("serve/cluster/c{conns}/b{batch}"), &front, conns * reqs, wall, &lat);
    stop.store(true, Ordering::SeqCst);
    handle.join().expect("front thread").expect("front run");
    if let Ok(front) = Arc::try_unwrap(front) {
        front.shutdown();
    }
    backend_stop.store(true, Ordering::SeqCst);
    backend_handle.join().expect("backend thread").expect("backend run");
    if let Ok(backend) = Arc::try_unwrap(backend) {
        backend.shutdown();
    }
    std::fs::remove_file(&sock).ok();
    result
}

/// The raw apply floor of the served model: minimum observed single-lane
/// `√K` panel apply, in µs, on the same N ≈ 200 native engine every
/// serve case runs — the physical lower bound any serve p50 rides on.
fn panel_apply_floor_us() -> f64 {
    let model: Arc<dyn GpModel> =
        ModelBuilder::from_config(ModelConfig::default()).build().expect("floor model");
    let dof = model.total_dof();
    let mut rng = Rng::new(7);
    let xi = rng.standard_normal_vec(dof);
    // Warm.
    let _ = model.apply_sqrt_panel(&xi, 1).expect("floor apply");
    let mut best = f64::INFINITY;
    for _ in 0..64 {
        let t = Instant::now();
        let _ = model.apply_sqrt_panel(&xi, 1).expect("floor apply");
        best = best.min(t.elapsed().as_secs_f64() * 1e6);
    }
    best
}

/// The `latency_budget` summary: serve p50/p99 per case expressed as a
/// multiple of the panel-apply floor (ROADMAP serving item).
fn latency_budget_json(floor_us: f64, results: &[CaseResult]) -> Value {
    let cases: Vec<Value> = results
        .iter()
        .map(|r| {
            json::obj(vec![
                ("name", json::s(&r.name)),
                ("p50_us", json::num(r.p50_us)),
                ("p99_us", json::num(r.p99_us)),
                ("p50_over_floor", json::num(if floor_us > 0.0 { r.p50_us / floor_us } else { 0.0 })),
                ("p99_over_floor", json::num(if floor_us > 0.0 { r.p99_us / floor_us } else { 0.0 })),
            ])
        })
        .collect();
    json::obj(vec![
        ("panel_apply_floor_us", json::num(floor_us)),
        (
            "floor_source",
            json::s(
                "inline: min single-lane apply on the default NATIVE N≈200 model — exact \
                 floor for serve/native/* and serve/cluster/* cases; approximate for other \
                 families",
            ),
        ),
        ("cases", json::arr(cases)),
    ])
}

fn main() {
    let mut json_out = false;
    let mut json_path = "BENCH_serve.json".to_string();
    for a in std::env::args().skip(1) {
        if a == "--json" {
            json_out = true;
        } else if let Some(p) = a.strip_prefix("--json=") {
            json_out = true;
            json_path = p.to_string();
        }
    }
    let reqs: usize = std::env::var("ICR_BENCH_SERVE_REQS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(200);

    println!("== serve throughput — connections × batch × model family (+ cluster) ==");
    println!(
        "{:<28} {:>10} {:>14} {:>10} {:>10} {:>10}",
        "case", "requests", "req/s", "p50_us", "p99_us", "mean_batch"
    );
    let print_row = |r: &CaseResult| {
        println!(
            "{:<28} {:>10} {:>14.0} {:>10.1} {:>10.1} {:>10.2}",
            r.name, r.requests, r.requests_per_sec, r.p50_us, r.p99_us, r.mean_batch
        );
    };
    let families = [("native", Backend::Native), ("kissgp", Backend::Kissgp)];
    let mut results: Vec<CaseResult> = Vec::new();
    for (family, backend) in families {
        for conns in [1usize, 4] {
            for batch in [1usize, 8] {
                let r = run_case(family, backend, conns, batch, reqs);
                print_row(&r);
                results.push(r);
            }
        }
    }
    // Cluster cases: front door + tcp backend, mixed-member routing.
    for conns in [1usize, 4] {
        let r = run_cluster_case(conns, 1, reqs);
        print_row(&r);
        results.push(r);
    }

    // Connections scaling: threads-per-session vs the event loop at the
    // same driver load, plus an event-only high-water case.
    let scale_conns: usize = std::env::var("ICR_BENCH_SERVE_SCALE_CONNS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2048);
    let scale_reqs: usize = std::env::var("ICR_BENCH_SERVE_SCALE_REQS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(50);
    let mid = 512.min(scale_conns);
    let mut plan: Vec<(IoMode, usize)> = vec![
        (IoMode::Threads, 64.min(scale_conns)),
        (IoMode::Threads, mid),
        (IoMode::Event, 64.min(scale_conns)),
        (IoMode::Event, mid),
    ];
    if scale_conns > mid {
        plan.push((IoMode::Event, scale_conns));
    }
    // (mode, conns, index into `results`) for the summary block.
    let mut scaling: Vec<(IoMode, usize, usize)> = Vec::new();
    for (mode, conns) in plan {
        let r = run_scaling_case(mode, conns, scale_reqs);
        print_row(&r);
        scaling.push((mode, conns, results.len()));
        results.push(r);
    }
    let rps_at = |mode: IoMode, conns: usize| {
        scaling
            .iter()
            .find(|(m, c, _)| *m == mode && *c == conns)
            .map(|(_, _, i)| results[*i].requests_per_sec)
    };
    let speedup_512 = match (rps_at(IoMode::Threads, mid), rps_at(IoMode::Event, mid)) {
        (Some(t), Some(e)) if t > 0.0 => e / t,
        _ => 0.0,
    };
    let max_event_connections = scaling
        .iter()
        .filter(|(m, _, _)| *m == IoMode::Event)
        .map(|(_, c, _)| *c)
        .max()
        .unwrap_or(0);
    println!(
        "connections_scaling: event/threads speedup at c{mid}: {speedup_512:.2}x | \
         max event connections: {max_event_connections}"
    );
    let connections_scaling = json::obj(vec![
        (
            "cases",
            json::arr(
                scaling
                    .iter()
                    .map(|(mode, conns, i)| {
                        let r = &results[*i];
                        json::obj(vec![
                            ("mode", json::s(mode.name())),
                            ("connections", json::num(*conns as f64)),
                            ("requests_per_sec", json::num(r.requests_per_sec)),
                            ("p50_us", json::num(r.p50_us)),
                            ("p99_us", json::num(r.p99_us)),
                            ("mean_batch", json::num(r.mean_batch)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("speedup_512", json::num(speedup_512)),
        ("max_event_connections", json::num(max_event_connections as f64)),
    ]);

    // Latency budget: serve latency over the raw apply floor.
    let floor_us = panel_apply_floor_us();
    println!("panel-apply floor (N≈200 native, single lane): {floor_us:.1} µs");
    for r in &results {
        println!(
            "  {:<26} p50 {:>8.1}x floor   p99 {:>8.1}x floor",
            r.name,
            if floor_us > 0.0 { r.p50_us / floor_us } else { 0.0 },
            if floor_us > 0.0 { r.p99_us / floor_us } else { 0.0 },
        );
    }

    if json_out {
        let doc = json::obj(vec![
            ("suite", json::s("serve_throughput")),
            ("version", json::s(icr::VERSION)),
            ("requests_per_client", json::num(reqs as f64)),
            ("hardware", hardware_json()),
            ("latency_budget", latency_budget_json(floor_us, &results)),
            ("connections_scaling", connections_scaling),
            ("results", json::arr(results.iter().map(CaseResult::to_json).collect())),
        ]);
        match std::fs::write(&json_path, format!("{}\n", doc.to_json_pretty())) {
            Ok(()) => println!("wrote {json_path}"),
            Err(e) => eprintln!("failed to write JSON results: {e}"),
        }
    }
}
