//! Serve-path throughput bench: a real Unix-socket server under
//! synchronous JSONL clients, swept over connections × batch × model
//! family. Reports requests/sec plus client-observed p50/p99 latency and
//! the realized mean batch size (cross-connection coalescing). Run with
//! `--json` to write `BENCH_serve.json` (overridable as `--json=path`),
//! embedding the same hardware metadata block as `BENCH_apply.json`:
//!
//! ```text
//! cargo bench --bench serve_throughput -- --json
//! ```
//!
//! Knobs: `ICR_BENCH_SERVE_REQS` (requests per client, default 200).

use std::io::{BufRead, BufReader, Write};
use std::os::unix::net::UnixStream;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

use icr::bench::hardware_json;
use icr::config::{Backend, ModelConfig, ServerConfig};
use icr::coordinator::Coordinator;
use icr::json::{self, Value};
use icr::net::{ListenAddr, NetServer};

struct CaseResult {
    name: String,
    requests: usize,
    requests_per_sec: f64,
    p50_us: f64,
    p99_us: f64,
    mean_batch: f64,
}

impl CaseResult {
    fn to_json(&self) -> Value {
        json::obj(vec![
            ("name", json::s(&self.name)),
            ("requests", json::num(self.requests as f64)),
            ("requests_per_sec", json::num(self.requests_per_sec)),
            ("p50_us", json::num(self.p50_us)),
            ("p99_us", json::num(self.p99_us)),
            ("mean_batch", json::num(self.mean_batch)),
        ])
    }
}

fn quantile(sorted_us: &[f64], q: f64) -> f64 {
    if sorted_us.is_empty() {
        return 0.0;
    }
    let idx = ((sorted_us.len() as f64 - 1.0) * q).round() as usize;
    sorted_us[idx.min(sorted_us.len() - 1)]
}

fn run_case(family: &str, backend: Backend, conns: usize, batch: usize, reqs: usize) -> CaseResult {
    let sock = std::env::temp_dir().join(format!(
        "icr_bench_{}_{family}_{conns}_{batch}.sock",
        std::process::id()
    ));
    let cfg = ServerConfig {
        model: ModelConfig::default(), // the paper's N ≈ 200 geometry
        backend,
        workers: 2,
        max_batch: 16,
        max_wait_us: 200,
        idle_timeout_ms: 0,
        listen: ListenAddr::Unix(sock.clone()),
        ..ServerConfig::default()
    };
    let coord = Arc::new(Coordinator::start(cfg.clone()).expect("coordinator"));
    let server = NetServer::bind(&cfg, coord.clone()).expect("bind");
    let stop = server.shutdown_handle();
    let handle = std::thread::spawn(move || server.run());

    let t0 = Instant::now();
    let mut all_lat_us: Vec<f64> = Vec::with_capacity(conns * reqs);
    std::thread::scope(|sc| {
        let mut threads = Vec::new();
        for c in 0..conns {
            let sock = sock.clone();
            threads.push(sc.spawn(move || {
                let stream = UnixStream::connect(&sock).expect("connect");
                let mut reader = BufReader::new(stream.try_clone().expect("clone"));
                let mut writer = stream;
                let mut lat = Vec::with_capacity(reqs);
                let mut line = String::new();
                for i in 0..reqs {
                    let seed = (c * reqs + i) as u64;
                    let t = Instant::now();
                    writeln!(
                        writer,
                        r#"{{"v": 2, "op": "sample", "id": {i}, "count": {batch}, "seed": {seed}}}"#
                    )
                    .expect("send");
                    writer.flush().expect("flush");
                    line.clear();
                    let n = reader.read_line(&mut line).expect("recv");
                    assert!(n > 0, "server hung up");
                    lat.push(t.elapsed().as_secs_f64() * 1e6);
                    assert!(line.contains("\"ok\":true"), "request failed: {line}");
                }
                lat
            }));
        }
        for t in threads {
            all_lat_us.extend(t.join().expect("client thread"));
        }
    });
    let wall = t0.elapsed().as_secs_f64();

    let applies = coord.metrics().counter("applies_executed").get() as f64;
    let batches = coord.metrics().histogram("batch_applies").count() as f64;
    stop.store(true, Ordering::SeqCst);
    handle.join().expect("server thread").expect("server run");
    if let Ok(coord) = Arc::try_unwrap(coord) {
        coord.shutdown();
    }
    std::fs::remove_file(&sock).ok();

    all_lat_us.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let total = conns * reqs;
    CaseResult {
        name: format!("serve/{family}/c{conns}/b{batch}"),
        requests: total,
        requests_per_sec: total as f64 / wall,
        p50_us: quantile(&all_lat_us, 0.50),
        p99_us: quantile(&all_lat_us, 0.99),
        mean_batch: if batches > 0.0 { applies / batches } else { 0.0 },
    }
}

fn main() {
    let mut json_out = false;
    let mut json_path = "BENCH_serve.json".to_string();
    for a in std::env::args().skip(1) {
        if a == "--json" {
            json_out = true;
        } else if let Some(p) = a.strip_prefix("--json=") {
            json_out = true;
            json_path = p.to_string();
        }
    }
    let reqs: usize = std::env::var("ICR_BENCH_SERVE_REQS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(200);

    println!("== serve throughput — connections × batch × model family ==");
    println!(
        "{:<28} {:>10} {:>14} {:>10} {:>10} {:>10}",
        "case", "requests", "req/s", "p50_us", "p99_us", "mean_batch"
    );
    let families = [("native", Backend::Native), ("kissgp", Backend::Kissgp)];
    let mut results: Vec<CaseResult> = Vec::new();
    for (family, backend) in families {
        for conns in [1usize, 4] {
            for batch in [1usize, 8] {
                let r = run_case(family, backend, conns, batch, reqs);
                println!(
                    "{:<28} {:>10} {:>14.0} {:>10.1} {:>10.1} {:>10.2}",
                    r.name, r.requests, r.requests_per_sec, r.p50_us, r.p99_us, r.mean_batch
                );
                results.push(r);
            }
        }
    }

    if json_out {
        let doc = json::obj(vec![
            ("suite", json::s("serve_throughput")),
            ("version", json::s(icr::VERSION)),
            ("requests_per_client", json::num(reqs as f64)),
            ("hardware", hardware_json()),
            ("results", json::arr(results.iter().map(CaseResult::to_json).collect())),
        ]);
        match std::fs::write(&json_path, format!("{}\n", doc.to_json_pretty())) {
            Ok(()) => println!("wrote {json_path}"),
            Err(e) => eprintln!("failed to write JSON results: {e}"),
        }
    }
}
