//! Coordinator benchmarks: request latency and batching throughput — L3
//! must not be the bottleneck (the paper's contribution is the engine).

use std::sync::Arc;

use icr::bench::Runner;
use icr::config::{ModelConfig, ServerConfig};
use icr::coordinator::{Coordinator, NativeEngine, Request, Response};
use icr::rng::Rng;

fn main() {
    let mut runner = Runner::new();

    let model = ModelConfig { target_n: 200, ..ModelConfig::default() };
    let engine = NativeEngine::from_config(&model).expect("engine");
    let dof = {
        use icr::coordinator::FieldEngine;
        engine.total_dof()
    };

    runner.header("engine floor (direct calls, no coordinator)");
    let mut rng = Rng::new(1);
    let xi = rng.standard_normal_vec(dof);
    let mut sink = 0.0;
    {
        use icr::coordinator::FieldEngine;
        runner.bench("direct/apply_sqrt/n200", || {
            sink += engine.apply_sqrt_batch(std::slice::from_ref(&xi)).unwrap()[0][0];
        });
    }
    std::hint::black_box(sink);

    runner.header("coordinator overhead and batching throughput");
    for &(workers, max_batch) in &[(1usize, 1usize), (2, 8), (4, 32)] {
        let cfg = ServerConfig {
            model: model.clone(),
            workers,
            max_batch,
            max_wait_us: 100,
            ..ServerConfig::default()
        };
        let coord = Arc::new(Coordinator::start(cfg).expect("coordinator"));

        // Single blocking request latency.
        let c2 = coord.clone();
        let mut seed = 0u64;
        runner.bench(&format!("coord/w{workers}_b{max_batch}/single_sample"), || {
            seed += 1;
            match c2.call(Request::Sample { count: 1, seed }).unwrap() {
                Response::Samples(s) => std::hint::black_box(s[0][0]),
                _ => unreachable!(),
            };
        });

        // Burst of 32 concurrent single-sample requests (throughput).
        let c3 = coord.clone();
        runner.bench(&format!("coord/w{workers}_b{max_batch}/burst32"), || {
            let pending: Vec<_> = (0..32)
                .map(|i| {
                    seed += 1;
                    c3.submit(Request::Sample { count: 1, seed: seed + i })
                })
                .collect();
            for (_, rx) in pending {
                rx.recv().unwrap().unwrap();
            }
        });

        Arc::try_unwrap(coord).ok().map(Coordinator::shutdown);
    }

    runner.header("inference step rate (Adam over loss_grad, native adjoint)");
    let cfg = ServerConfig { model: model.clone(), workers: 1, ..ServerConfig::default() };
    let coord = Coordinator::start(cfg).expect("coordinator");
    let n_obs = {
        use icr::coordinator::FieldEngine;
        coord.engine().obs_indices().len()
    };
    let mut rng = Rng::new(2);
    let y = rng.standard_normal_vec(n_obs);
    runner.bench("coord/infer_50steps/n200", || {
        match coord
            .call(Request::Infer { y_obs: y.clone(), sigma_n: 0.3, steps: 50, lr: 0.1 })
            .unwrap()
        {
            Response::Inference { trace, .. } => std::hint::black_box(trace.losses[49]),
            _ => unreachable!(),
        };
    });
    coord.shutdown();

    runner.dump_jsonl("results/bench_coordinator.jsonl").ok();
}
