//! Batched `loss_grad` bench: multi-chain inference sweeps through the
//! blocked panel path vs per-chain serial `loss_grad` calls, swept over
//! chain count B × threads × N. This is the inference-side twin of
//! `apply_panel` (`DESIGN.md` §7): run with `--json` to write
//! `BENCH_loss_grad.json` (overridable as `--json=path`), e.g.
//!
//! ```text
//! cargo bench --bench loss_grad_panel -- --json
//! ```

use icr::bench::Runner;
use icr::config::ModelConfig;
use icr::json;
use icr::model::{GpModel, NativeEngine};
use icr::parallel::Exec;
use icr::rng::Rng;

/// Deep refinement geometry (mirrors `apply_panel`): enough levels that
/// the dense base-level apply stays negligible at every N.
fn deep_config(target: usize) -> ModelConfig {
    let mut lvl = 5;
    loop {
        let cfg =
            ModelConfig { n_csz: 5, n_fsz: 4, n_lvl: lvl, target_n: target, ..ModelConfig::default() };
        match cfg.refinement_params() {
            Ok(p) if p.n0 <= 64 || lvl >= 12 => return cfg,
            _ => lvl += 1,
        }
    }
}

fn median(runner: &Runner, name: &str) -> Option<f64> {
    runner.results.iter().find(|r| r.name == name).map(|r| r.median_ns)
}

fn main() {
    let mut runner = Runner::new();
    runner.header("batched loss_grad — chains × threads × N");
    let sizes = [1024usize, 4096];
    let threads = [1usize, 2, 4];
    let batches = [1usize, 4, 8];

    let mut rng = Rng::new(7117);
    for &target in &sizes {
        let cfg = deep_config(target);
        for &t in &threads {
            let model = NativeEngine::from_config(&cfg)
                .expect("native engine")
                .with_exec(Exec::pooled(t));
            let n = model.n_points();
            let dof = model.total_dof();
            let y = rng.standard_normal_vec(model.obs_indices().len());
            let sigma = 0.2;
            for &b in &batches {
                let panel = rng.standard_normal_vec(b * dof);
                let mut losses = vec![0.0; b];
                let mut grad = vec![0.0; b * dof];
                let mut sink = 0.0;

                // Baseline (t = 1 only): B sequential single-chain
                // loss_grad calls — what a multi-restart loop used to
                // cost per sweep.
                if t == 1 {
                    runner.bench(&format!("loss_grad/serial/b{b}/n{n}"), || {
                        for c in 0..b {
                            let (l, _g) = model
                                .loss_grad(&panel[c * dof..(c + 1) * dof], &y, sigma)
                                .expect("loss_grad");
                            sink += l;
                        }
                    });
                }

                // Batched panel sweep: one forward + one adjoint panel
                // apply for all B chains, buffers reused across calls.
                runner.bench(&format!("loss_grad/panel/b{b}/t{t}/n{n}"), || {
                    model
                        .loss_grad_panel_into(&panel, b, &y, sigma, &mut losses, &mut grad)
                        .expect("loss_grad_panel");
                    sink += losses[0] + grad[0];
                });
                std::hint::black_box(sink);
            }
        }
    }

    // Summaries: panel-vs-serial speedup per (B, N) at t = 1 and thread
    // scaling of the B = 8 panel sweep.
    let mut summary: Vec<json::Value> = Vec::new();
    for &target in &sizes {
        let cfg = deep_config(target);
        let n = cfg.refinement_params().expect("params").final_size();
        for &b in &batches {
            let serial = median(&runner, &format!("loss_grad/serial/b{b}/n{n}"));
            let panel = median(&runner, &format!("loss_grad/panel/b{b}/t1/n{n}"));
            if let (Some(serial), Some(panel)) = (serial, panel) {
                let speedup = serial / panel;
                println!(
                    "loss_grad n={n}: panel(B={b}, t=1) speedup over {b} serial = {speedup:.2}x"
                );
                summary.push(json::obj(vec![
                    ("metric", json::s("loss_grad_panel_vs_serial")),
                    ("n", json::num(n as f64)),
                    ("batch", json::num(b as f64)),
                    ("speedup", json::num(speedup)),
                ]));
            }
        }
        let t1 = median(&runner, &format!("loss_grad/panel/b8/t1/n{n}"));
        for &t in &[2usize, 4] {
            if let (Some(t1), Some(tt)) =
                (t1, median(&runner, &format!("loss_grad/panel/b8/t{t}/n{n}")))
            {
                let scaling = t1 / tt;
                println!("loss_grad n={n}: thread scaling t{t}/t1 (B=8) = {scaling:.2}x");
                summary.push(json::obj(vec![
                    ("metric", json::s("loss_grad_thread_scaling")),
                    ("n", json::num(n as f64)),
                    ("threads", json::num(t as f64)),
                    ("speedup", json::num(scaling)),
                ]));
            }
        }
    }

    runner.dump_jsonl("results/bench_loss_grad.jsonl").ok();
    if runner.json_requested() {
        match runner.dump_json(
            "BENCH_loss_grad.json",
            "loss_grad_panel",
            vec![("summary", json::arr(summary))],
        ) {
            Ok(path) => println!("wrote {}", path.display()),
            Err(e) => eprintln!("failed to write JSON results: {e}"),
        }
    }
}
