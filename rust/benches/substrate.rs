//! Substrate micro-benchmarks: FFT, dense linear algebra, Krylov solvers
//! and the RNG — the building blocks whose costs compose into Fig. 4.

use icr::bench::Runner;
use icr::fft::{circulant_matvec, fft_in_place, Complex};
use icr::gp::kernel_matrix;
use icr::kernels::Matern;
use icr::kissgp::{conjugate_gradient, lanczos_logdet};
use icr::linalg::{Cholesky, Matrix};
use icr::rng::Rng;

fn main() {
    let mut runner = Runner::new();
    let mut rng = Rng::new(9);

    runner.header("FFT (KISS-GP's harmonic representation, Eq. 15)");
    for &n in &[1024usize, 8192, 65536] {
        let mut buf: Vec<Complex> =
            (0..n).map(|_| Complex::new(rng.standard_normal(), rng.standard_normal())).collect();
        runner.bench(&format!("fft/complex/n{n}"), || {
            fft_in_place(&mut buf, false);
        });
        let c = rng.standard_normal_vec(n);
        let x = rng.standard_normal_vec(n);
        let mut sink = 0.0;
        runner.bench(&format!("fft/circulant_matvec/n{n}"), || {
            sink += circulant_matvec(&c, &x)[0];
        });
        std::hint::black_box(sink);
    }

    runner.header("dense linear algebra (base level + refinement matrices)");
    for &n in &[5usize, 13, 64, 200] {
        let kernel = Matern::nu32(1.0, 1.0);
        let pts: Vec<f64> = (0..n).map(|i| (0.05 * i as f64).exp()).collect();
        let k = kernel_matrix(&kernel, &pts);
        let mut sink = 0.0;
        runner.bench(&format!("linalg/cholesky/n{n}"), || {
            sink += Cholesky::new(&k).unwrap().logdet();
        });
        std::hint::black_box(sink);
    }
    let a = Matrix::from_fn(128, 128, |r, c| ((r * 13 + c) as f64 * 0.1).sin());
    let b = Matrix::from_fn(128, 128, |r, c| ((r + 7 * c) as f64 * 0.1).cos());
    let mut sink = 0.0;
    runner.bench("linalg/matmul/n128", || {
        sink += a.matmul(&b)[(0, 0)];
    });
    std::hint::black_box(sink);

    runner.header("Krylov solvers (the paper's KISS-GP budget: CG-40, SLQ 10x15)");
    let kernel = Matern::nu32(1.0, 1.0);
    let pts: Vec<f64> = (0..512).map(|i| i as f64 * 0.1).collect();
    let k = kernel_matrix(&kernel, &pts);
    let mut kj = k.clone();
    for i in 0..512 {
        kj[(i, i)] += 1e-3;
    }
    let y = rng.standard_normal_vec(512);
    let mut sink = 0.0;
    runner.bench("krylov/cg40_dense_mvm/n512", || {
        sink += conjugate_gradient(|v| kj.matvec(v), &y, 40, 0.0).0[0];
    });
    let mut probe_rng = Rng::new(4);
    runner.bench("krylov/slq_10x15_dense_mvm/n512", || {
        sink += lanczos_logdet(|v| kj.matvec(v), 512, 10, 15, &mut probe_rng);
    });
    std::hint::black_box(sink);

    runner.header("RNG (excitation generation on the sampling path)");
    let mut buf = vec![0.0; 4096];
    runner.bench("rng/standard_normal_4096", || {
        rng.fill_standard_normal(&mut buf);
    });
    std::hint::black_box(buf[0]);

    runner.dump_jsonl("results/bench_substrate.jsonl").ok();
}
