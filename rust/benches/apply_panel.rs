//! Panel-apply bench: blocked multi-excitation `√K` applies vs serial
//! single applies, swept over batch size × thread count × N, forward and
//! adjoint. This is the perf trajectory of the batched execution path
//! (`DESIGN.md` §6): run with `--json` to write `BENCH_apply.json`
//! (overridable as `--json=path`), e.g.
//!
//! ```text
//! cargo bench --bench apply_panel -- --json
//! ```

use icr::bench::Runner;
use icr::chart::IdentityChart;
use icr::experiments::paper_chart;
use icr::icr::{IcrEngine, PanelWorkspace, RefinementParams};
use icr::json;
use icr::kernels::Matern;
use icr::parallel::Exec;
use icr::rng::Rng;

/// Deep refinement geometry: enough levels that the dense base-level
/// apply stays negligible even at the largest N (the asymptotic regime
/// the O(N) claim is about).
fn deep_params(target: usize) -> RefinementParams {
    let mut lvl = 5;
    loop {
        let p = RefinementParams::for_target(5, 4, lvl, target).expect("refinement params");
        if p.n0 <= 64 || lvl >= 12 {
            return p;
        }
        lvl += 1;
    }
}

fn median(runner: &Runner, name: &str) -> Option<f64> {
    runner.results.iter().find(|r| r.name == name).map(|r| r.median_ns)
}

fn main() {
    let mut runner = Runner::new();
    runner.header("blocked √K panel apply — batch × threads × N");
    let kernel = Matern::nu32(1.0, 1.0);
    let sizes = [1024usize, 4096, 16384];
    let threads = [1usize, 2, 4];
    const B: usize = 8;

    let mut rng = Rng::new(4242);
    for &target in &sizes {
        let params = deep_params(target);
        let chart = paper_chart(params, 0.02, 1.0);
        let engine = IcrEngine::build(&kernel, &chart, params).expect("charted engine");
        let n = engine.n_points();
        let dof = engine.total_dof();
        let panel = rng.standard_normal_vec(B * dof);
        let gpanel = rng.standard_normal_vec(B * n);
        let mut ws = PanelWorkspace::new();
        let mut out = vec![0.0; B * n];
        let mut gout = vec![0.0; B * dof];
        let mut sink = 0.0;

        // Baseline: B sequential single-excitation applies (the pre-panel
        // serving path — what a coalesced batch used to cost).
        runner.bench(&format!("apply/serial/b{B}/n{n}"), || {
            for b in 0..B {
                sink += engine.apply_sqrt(&panel[b * dof..(b + 1) * dof])[0];
            }
        });
        runner.bench(&format!("transpose/serial/b{B}/n{n}"), || {
            for b in 0..B {
                sink += engine.apply_sqrt_transpose(&gpanel[b * n..(b + 1) * n])[0];
            }
        });

        // Blocked panel applies: scoped-spawn baseline vs the persistent
        // worker pool at every thread count (t = 1 shares the serial
        // path, so only the scoped name is recorded there).
        for &t in &threads {
            runner.bench(&format!("apply/panel/b{B}/t{t}/n{n}"), || {
                engine.apply_sqrt_multi_with(&panel, B, t, &mut ws, &mut out);
                sink += out[0];
            });
            runner.bench(&format!("transpose/panel/b{B}/t{t}/n{n}"), || {
                engine.apply_sqrt_transpose_multi_with(&gpanel, B, t, &mut ws, &mut gout);
                sink += gout[0];
            });
            if t > 1 {
                let exec = Exec::pooled(t);
                runner.bench(&format!("apply/pool/b{B}/t{t}/n{n}"), || {
                    engine.apply_sqrt_panel_exec(&panel, B, &exec, &mut ws, &mut out);
                    sink += out[0];
                });
                runner.bench(&format!("transpose/pool/b{B}/t{t}/n{n}"), || {
                    engine.apply_sqrt_transpose_panel_exec(&gpanel, B, &exec, &mut ws, &mut gout);
                    sink += gout[0];
                });
            }
        }

        // SIMD-off (pure scalar) reference at t = 1 so the microkernel
        // win is visible in the JSON trajectory.
        {
            let scalar = IcrEngine::build(&kernel, &chart, params)
                .expect("scalar engine")
                .with_simd(false);
            runner.bench(&format!("apply/scalar/b{B}/t1/n{n}"), || {
                scalar.apply_sqrt_multi_with(&panel, B, 1, &mut ws, &mut out);
                sink += out[0];
            });
        }

        // Single-lane panel: window parallelism without batching.
        for &t in &[1usize, 2] {
            runner.bench(&format!("apply/panel/b1/t{t}/n{n}"), || {
                engine.apply_sqrt_multi_with(&panel[..dof], 1, t, &mut ws, &mut out[..n]);
                sink += out[0];
            });
        }
        std::hint::black_box(sink);
    }

    // One stationary lane at the largest N: the broadcast fast path also
    // benefits from lane blocking (R stays cache-resident, lanes share it).
    {
        let target = *sizes.last().unwrap();
        let params = deep_params(target);
        let engine = IcrEngine::build(&kernel, &IdentityChart::unit(), params)
            .expect("stationary engine");
        assert!(engine.is_stationary());
        let n = engine.n_points();
        let dof = engine.total_dof();
        let panel = rng.standard_normal_vec(B * dof);
        let mut ws = PanelWorkspace::new();
        let mut out = vec![0.0; B * n];
        let mut sink = 0.0;
        runner.bench(&format!("apply_stationary/serial/b{B}/n{n}"), || {
            for b in 0..B {
                sink += engine.apply_sqrt(&panel[b * dof..(b + 1) * dof])[0];
            }
        });
        runner.bench(&format!("apply_stationary/panel/b{B}/t1/n{n}"), || {
            engine.apply_sqrt_multi_with(&panel, B, 1, &mut ws, &mut out);
            sink += out[0];
        });
        std::hint::black_box(sink);
    }

    // Summaries: batching speedup (panel t1 vs B serial singles) and
    // thread scaling (t1 vs t2/t4) per N, printed and persisted.
    let mut summary: Vec<json::Value> = Vec::new();
    for &target in &sizes {
        let params = deep_params(target);
        let n = params.final_size();
        let serial = median(&runner, &format!("apply/serial/b{B}/n{n}"));
        let t1 = median(&runner, &format!("apply/panel/b{B}/t1/n{n}"));
        if let (Some(serial), Some(t1)) = (serial, t1) {
            let speedup = serial / t1;
            println!("apply n={n}: panel(B={B}, t=1) speedup over {B} serial singles = {speedup:.2}x");
            summary.push(json::obj(vec![
                ("metric", json::s("apply_panel_vs_serial")),
                ("n", json::num(n as f64)),
                ("batch", json::num(B as f64)),
                ("speedup", json::num(speedup)),
            ]));
        }
        for &t in &[2usize, 4] {
            if let (Some(t1), Some(tt)) =
                (t1, median(&runner, &format!("apply/panel/b{B}/t{t}/n{n}")))
            {
                let scaling = t1 / tt;
                println!("apply n={n}: thread scaling t{t}/t1 = {scaling:.2}x");
                summary.push(json::obj(vec![
                    ("metric", json::s("apply_thread_scaling")),
                    ("n", json::num(n as f64)),
                    ("threads", json::num(t as f64)),
                    ("speedup", json::num(scaling)),
                ]));
            }
            // Pool vs scoped-spawn at the same thread count: the
            // persistent-pool dispatch must not lose to per-level spawns
            // (and should win at small N, where spawn cost dominates).
            if let (Some(scoped), Some(pool)) = (
                median(&runner, &format!("apply/panel/b{B}/t{t}/n{n}")),
                median(&runner, &format!("apply/pool/b{B}/t{t}/n{n}")),
            ) {
                let speedup = scoped / pool;
                println!("apply n={n}: pool vs scoped at t{t} = {speedup:.2}x");
                summary.push(json::obj(vec![
                    ("metric", json::s("apply_pool_vs_scoped")),
                    ("n", json::num(n as f64)),
                    ("threads", json::num(t as f64)),
                    ("speedup", json::num(speedup)),
                ]));
            }
        }
        if let (Some(scalar), Some(simd)) = (
            median(&runner, &format!("apply/scalar/b{B}/t1/n{n}")),
            median(&runner, &format!("apply/panel/b{B}/t1/n{n}")),
        ) {
            let speedup = scalar / simd;
            println!("apply n={n}: simd vs scalar at t1 = {speedup:.2}x");
            summary.push(json::obj(vec![
                ("metric", json::s("apply_simd_vs_scalar")),
                ("n", json::num(n as f64)),
                ("speedup", json::num(speedup)),
            ]));
        }
    }

    runner.dump_jsonl("results/bench_apply.jsonl").ok();
    if runner.json_requested() {
        match runner.dump_json("BENCH_apply.json", "apply_panel", vec![("summary", json::arr(summary))]) {
            Ok(path) => println!("wrote {}", path.display()),
            Err(e) => eprintln!("failed to write JSON results: {e}"),
        }
    }
}
