"""Refinement-matrix construction (paper Eqs. 5-9) in JAX.

For each window of ``n_csz`` coarse pixels refined to ``n_fsz`` fine pixels:

    R = K_fc @ inv(K_cc)                    (Eq. 7)
    D = K_ff - K_fc @ inv(K_cc) @ K_cf      (Eq. 8)
    s_f = R @ s_c + cholesky(D) @ xi        (Eq. 9)

with all kernel blocks evaluated at the *charted* locations (§4.3).
Stationary (affine-chart) levels get one broadcast pair; charted levels
get per-window stacks built with ``vmap``.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .geometry import RefinementParams, build_positions


def _kernel_matrix(kernel, xa, xb):
    return kernel.eval(jnp.abs(xa[:, None] - xb[None, :]))


def window_matrices(kernel, chart, coarse_u, fine_u, jitter: float = 0.0):
    """``(R, sqrtD)`` for one window from Euclidean grid coordinates.

    ``coarse_u``: (n_csz,) grid coords; ``fine_u``: (n_fsz,) grid coords.
    Returns ``R`` of shape (n_fsz, n_csz) and lower-triangular ``sqrtD`` of
    shape (n_fsz, n_fsz).
    """
    xc = chart.to_domain(jnp.asarray(coarse_u))
    xf = chart.to_domain(jnp.asarray(fine_u))
    kcc = _kernel_matrix(kernel, xc, xc)
    kfc = _kernel_matrix(kernel, xf, xc)
    kff = _kernel_matrix(kernel, xf, xf)
    if jitter:
        kcc = kcc + jitter * jnp.eye(kcc.shape[0])
    # R = K_fc K_cc^{-1} via a symmetric solve: R^T = K_cc^{-1} K_cf.
    r = jax.scipy.linalg.solve(kcc, kfc.T, assume_a="pos").T
    d = kff - r @ kfc.T
    d = 0.5 * (d + d.T)
    d = d + 1e-13 * kernel.variance() * jnp.eye(d.shape[0])
    sqrt_d = jnp.linalg.cholesky(d)
    return r, sqrt_d


@dataclasses.dataclass
class LevelMatrices:
    """Matrices of one refinement level.

    ``r``: (n_fsz, n_csz) if stationary else (n_windows, n_fsz, n_csz);
    ``sqrt_d`` analogous with trailing (n_fsz, n_fsz).
    """

    r: jnp.ndarray
    sqrt_d: jnp.ndarray
    stationary: bool


@dataclasses.dataclass
class IcrModel:
    """A fully constructed ICR model: geometry + matrices (L2 state).

    Mirrors ``rust/src/icr/engine.rs::IcrEngine``. The apply itself lives
    in ``kernels/refine.py`` (Pallas, L1) and ``kernels/ref.py`` (oracle).
    """

    params: RefinementParams
    positions: List[np.ndarray]
    base_sqrt: jnp.ndarray
    levels: List[LevelMatrices]
    domain_points: np.ndarray
    kernel_name: str
    chart_name: str


def build_icr_model(kernel, chart, params: RefinementParams) -> IcrModel:
    """Construct base Cholesky + per-level refinement matrices (§4.4 cost:
    O(max(n_csz, n_fsz)^3 · N), amortized once per hyper-parameter set)."""
    positions = [np.asarray(p, dtype=np.float64) for p in build_positions(params)]

    base_u = jnp.asarray(positions[0])
    xb = chart.to_domain(base_u)
    k0 = _kernel_matrix(kernel, xb, xb)
    k0 = k0 + 1e-13 * kernel.variance() * jnp.eye(k0.shape[0])
    base_sqrt = jnp.linalg.cholesky(k0)

    stationary = bool(getattr(chart, "is_affine", False))
    levels: List[LevelMatrices] = []
    for l in range(params.n_lvl):
        coarse = positions[l]
        fine = positions[l + 1]
        nw = params.n_windows(len(coarse))
        if stationary:
            r, sd = window_matrices(kernel, chart, coarse[: params.n_csz], fine[: params.n_fsz])
            levels.append(LevelMatrices(r=r, sqrt_d=sd, stationary=True))
        else:
            s = params.stride
            cw = np.stack(
                [coarse[w * s : w * s + params.n_csz] for w in range(nw)]
            )  # (nw, csz)
            fw = np.stack(
                [fine[w * params.n_fsz : (w + 1) * params.n_fsz] for w in range(nw)]
            )  # (nw, fsz)
            build = jax.vmap(lambda c, f: window_matrices(kernel, chart, c, f))
            r, sd = build(jnp.asarray(cw), jnp.asarray(fw))
            levels.append(LevelMatrices(r=r, sqrt_d=sd, stationary=False))

    domain_points = np.asarray(chart.to_domain(jnp.asarray(positions[-1])))
    return IcrModel(
        params=params,
        positions=positions,
        base_sqrt=base_sqrt,
        levels=levels,
        domain_points=domain_points,
        kernel_name=getattr(kernel, "name", "unknown"),
        chart_name=getattr(chart, "name", "unknown"),
    )


def split_excitations(params: RefinementParams, xi_flat) -> Sequence[jnp.ndarray]:
    """Split a flat excitation vector into per-level chunks
    ``[(n0,), (n1,), ...]`` matching the Rust engine's flat layout."""
    sizes = params.excitation_sizes()
    out = []
    off = 0
    for n in sizes:
        out.append(xi_flat[off : off + n])
        off += n
    assert off == params.total_dof()
    return out
