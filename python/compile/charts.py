"""Coordinate charts (paper §4.3) — build-time mirror of ``rust/src/chart``.

ICR refines on a regular Euclidean grid; a user-provided chart ``phi^{-1}``
maps grid coordinates into the modeled domain, and the kernel is evaluated
there. The Rust-native engine and the JAX/Pallas artifacts must agree on
this geometry bit-for-bit (up to f64 round-off): the artifact-gated
integration tests in ``rust/tests/`` compare the two numerically.
"""

from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class IdentityChart:
    """Affine chart ``x = offset + scale * u`` (the plain regular grid)."""

    offset: float = 0.0
    scale: float = 1.0

    name = "identity"
    is_affine = True

    def to_domain(self, u):
        return self.offset + self.scale * u

    def to_grid(self, x):
        return (x - self.offset) / self.scale


@dataclasses.dataclass(frozen=True)
class LogChart:
    """Logarithmic chart ``x = exp(alpha + beta * u)`` — the §5 geometry."""

    alpha: float
    beta: float

    name = "log"
    is_affine = False

    def to_domain(self, u):
        import jax.numpy as jnp

        return jnp.exp(self.alpha + self.beta * u)

    def to_grid(self, x):
        import jax.numpy as jnp

        return (jnp.log(x) - self.alpha) / self.beta

    @staticmethod
    def from_neighbor_distances(n: int, d_min: float, d_max: float, u0: float = 0.0) -> "LogChart":
        """Chart whose unit-spaced grid of ``n`` points starting at ``u0``
        has nearest-neighbour *domain* distances sweeping ``d_min → d_max``
        (paper §5.1: 2%·rho_0 … rho_0 over N ≈ 200 points)."""
        assert n >= 3 and 0 < d_min < d_max
        beta = math.log(d_max / d_min) / (n - 2)
        alpha = math.log(d_min / (math.expm1(beta))) - beta * u0
        return LogChart(alpha=alpha, beta=beta)


@dataclasses.dataclass(frozen=True)
class PowerChart:
    """Power-law chart ``x = x0 * (1 + u/u0)^gamma`` (radial stretches)."""

    x0: float
    u0: float
    gamma: float

    name = "power"
    is_affine = False

    def to_domain(self, u):
        return self.x0 * (1.0 + u / self.u0) ** self.gamma

    def to_grid(self, x):
        return self.u0 * ((x / self.x0) ** (1.0 / self.gamma) - 1.0)
