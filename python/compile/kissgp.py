"""KISS-GP baseline in JAX (paper Eq. 15, §5.2 timing protocol).

The JAX twin of ``rust/src/kissgp``: the same `W·F·P·Fᵀ·Wᵀ` structure with
a fixed 40-iteration CG inverse and a 10-probe × 15-step stochastic
Lanczos log-determinant, written with ``lax``-friendly control flow so the
whole forward pass lowers to a single HLO executable (the PJRT lane of the
Fig. 4 benchmark).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


@dataclasses.dataclass
class KissGpOperator:
    """Baked KISS-GP representation for a fixed point set + kernel."""

    idx: jnp.ndarray  # (N,) left inducing index per point
    w_left: jnp.ndarray  # (N,) left interpolation weight
    spectrum: jnp.ndarray  # (n_fft,) circulant eigenvalues of K_UU
    m: int
    n_fft: int
    jitter: float
    cg_iters: int
    lanczos_iters: int


def build_kissgp(kernel, points, m: int, padding: float, jitter: float,
                 cg_iters: int = 40, lanczos_iters: int = 15) -> KissGpOperator:
    """Construct the operator (mirrors ``rust/src/kissgp/model.rs``)."""
    pts = np.asarray(points, dtype=np.float64)
    lo, hi = float(pts.min()), float(pts.max())
    spacing = (hi - lo) / (m - 1)
    t = np.clip((pts - lo) / spacing, 0.0, m - 1.0)
    idx = np.minimum(np.floor(t).astype(np.int64), m - 2)
    w_left = 1.0 - (t - idx)

    n_fft = _next_pow2(max(2, int(np.ceil(m * (1.0 + padding)))))
    j = np.arange(n_fft)
    wrap = np.minimum(j, n_fft - j)
    col = np.asarray(kernel.eval(jnp.asarray(wrap * spacing)))
    spectrum = np.real(np.fft.fft(col))

    return KissGpOperator(
        idx=jnp.asarray(idx),
        w_left=jnp.asarray(w_left),
        spectrum=jnp.asarray(spectrum),
        m=m,
        n_fft=n_fft,
        jitter=jitter,
        cg_iters=cg_iters,
        lanczos_iters=lanczos_iters,
    )


def apply_k(op: KissGpOperator, v):
    """`(K_KISS + jitter·I)·v` in O(N + M log M)."""
    # Wᵀ·v: scatter-add the two weights per modeled point.
    wt = jnp.zeros(op.m, dtype=v.dtype)
    wt = wt.at[op.idx].add(op.w_left * v)
    wt = wt.at[op.idx + 1].add((1.0 - op.w_left) * v)
    # K_UU via the circulant embedding.
    padded = jnp.zeros(op.n_fft, dtype=v.dtype).at[: op.m].set(wt)
    kw = jnp.real(jnp.fft.ifft(jnp.fft.fft(padded) * op.spectrum))[: op.m]
    # W·(K_UU Wᵀ v).
    y = op.w_left * kw[op.idx] + (1.0 - op.w_left) * kw[op.idx + 1]
    return y + op.jitter * v


def cg_solve(op: KissGpOperator, b, iters: int):
    """Fixed-budget conjugate gradients (paper: 40 iterations, no early
    exit — the timed operation must have deterministic cost)."""

    def body(_, state):
        x, r, p, rs_old = state
        ap = apply_k(op, p)
        denom = jnp.dot(p, ap)
        alpha = rs_old / jnp.where(jnp.abs(denom) < 1e-300, 1e-300, denom)
        x = x + alpha * p
        r = r - alpha * ap
        rs_new = jnp.dot(r, r)
        beta = rs_new / jnp.where(rs_old < 1e-300, 1e-300, rs_old)
        p = r + beta * p
        return (x, r, p, rs_new)

    x0 = jnp.zeros_like(b)
    state = (x0, b, b, jnp.dot(b, b))
    x, r, _, _ = jax.lax.fori_loop(0, iters, body, state)
    return x, jnp.sqrt(jnp.dot(r, r))


def jacobi_eigh_small(t, sweeps: int = 8):
    """Eigen-decomposition of a small symmetric matrix via cyclic Jacobi,
    in pure jnp ops.

    ``jnp.linalg.eigh`` lowers to a LAPACK *custom-call*
    (``lapack_dsyevd_ffi``) that the offline xla_extension 0.5.1 runtime
    cannot execute; Jacobi sweeps lower to plain HLO and are exact enough
    for the 15×15 Lanczos tridiagonals of the SLQ estimator (mirrors
    ``rust/src/linalg/eigen.rs``).

    Returns ``(eigenvalues, eigenvectors)`` with columns as eigenvectors.
    """
    k = t.shape[0]
    pairs = jnp.asarray(
        [(p, q) for p in range(k) for q in range(p + 1, k)], dtype=jnp.int32
    )
    pairs = jnp.tile(pairs, (sweeps, 1))

    def rotate(carry, pq):
        a, v = carry
        p, q = pq[0], pq[1]
        app, aqq, apq = a[p, p], a[q, q], a[p, q]
        # Stable rotation (Golub & Van Loan §8.4); skip when already zero.
        safe_apq = jnp.where(jnp.abs(apq) < 1e-300, 1.0, apq)
        tau = (aqq - app) / (2.0 * safe_apq)
        tt = jnp.sign(tau) / (jnp.abs(tau) + jnp.sqrt(1.0 + tau * tau))
        tt = jnp.where(tau == 0.0, 1.0, tt)
        c = 1.0 / jnp.sqrt(1.0 + tt * tt)
        s = tt * c
        c = jnp.where(jnp.abs(apq) < 1e-300, 1.0, c)
        s = jnp.where(jnp.abs(apq) < 1e-300, 0.0, s)
        # a ← Gᵀ a G with G the (p,q) rotation.
        row_p, row_q = a[p, :], a[q, :]
        a = a.at[p, :].set(c * row_p - s * row_q)
        a = a.at[q, :].set(s * row_p + c * row_q)
        col_p, col_q = a[:, p], a[:, q]
        a = a.at[:, p].set(c * col_p - s * col_q)
        a = a.at[:, q].set(s * col_p + c * col_q)
        vp, vq = v[:, p], v[:, q]
        v = v.at[:, p].set(c * vp - s * vq)
        v = v.at[:, q].set(s * vp + c * vq)
        return (a, v), None

    (a, v), _ = jax.lax.scan(rotate, (t, jnp.eye(k, dtype=t.dtype)), pairs)
    return jnp.diagonal(a), v


def lanczos_logdet(op: KissGpOperator, probes, iters: int):
    """Stochastic Lanczos quadrature log-det (paper: 10 probes × 15 iters).

    ``probes``: (P, N) Rademacher vectors supplied by the caller (the Rust
    coordinator generates them so results are reproducible across lanes).
    """
    n = probes.shape[1]

    def one_probe(z):
        norm0 = jnp.sqrt(jnp.dot(z, z))
        v = z / norm0

        def step(carry, _):
            v, v_prev, beta = carry
            w = apply_k(op, v)
            alpha = jnp.dot(w, v)
            w = w - alpha * v - beta * v_prev
            beta_new = jnp.sqrt(jnp.dot(w, w))
            v_new = w / jnp.where(beta_new < 1e-300, 1e-300, beta_new)
            return (v_new, v, beta_new), (alpha, beta_new)

        (_, _, _), (alphas, betas) = jax.lax.scan(
            step, (v, jnp.zeros_like(v), jnp.asarray(0.0, v.dtype)), None, length=iters
        )
        t = jnp.diag(alphas) + jnp.diag(betas[:-1], 1) + jnp.diag(betas[:-1], -1)
        evals, evecs = jacobi_eigh_small(t)
        tau = evecs[0, :]
        lam = jnp.maximum(evals, 1e-300)
        return jnp.asarray(n, lam.dtype) * jnp.sum(tau * tau * jnp.log(lam))

    return jnp.mean(jax.vmap(one_probe)(probes))


def kissgp_forward(op: KissGpOperator, y, probes) -> Tuple:
    """The paper's timed forward pass: CG solve + SLQ log-det."""
    x, residual = cg_solve(op, y, op.cg_iters)
    logdet = lanczos_logdet(op, probes, op.lanczos_iters)
    return x, logdet, residual
