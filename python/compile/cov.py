"""Stationary isotropic covariance kernels (paper §3.1, Eq. 14).

Mirror of ``rust/src/kernels``; written against ``jax.numpy`` so the same
functions serve eager construction, tracing and Pallas reference oracles.
"""

from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Matern:
    """Matérn-nu kernel; ``nu32`` is the paper's Eq. 14."""

    nu: float
    rho: float
    amplitude: float = 1.0

    @property
    def name(self) -> str:
        return {0.5: "matern12", 1.5: "matern32", 2.5: "matern52"}[self.nu]

    def variance(self) -> float:
        return self.amplitude**2

    def eval(self, d):
        d = jnp.abs(d)
        a2 = self.amplitude**2
        r = d / self.rho
        if self.nu == 0.5:
            return a2 * jnp.exp(-r)
        if self.nu == 1.5:
            s = math.sqrt(3.0) * r
            return a2 * (1.0 + s) * jnp.exp(-s)
        if self.nu == 2.5:
            s = math.sqrt(5.0) * r
            return a2 * (1.0 + s + s * s / 3.0) * jnp.exp(-s)
        raise ValueError(f"unsupported nu = {self.nu}")


def matern12(rho: float, amplitude: float = 1.0) -> Matern:
    return Matern(0.5, rho, amplitude)


def matern32(rho: float, amplitude: float = 1.0) -> Matern:
    """The paper's experiment kernel (Eq. 14)."""
    return Matern(1.5, rho, amplitude)


def matern52(rho: float, amplitude: float = 1.0) -> Matern:
    return Matern(2.5, rho, amplitude)


@dataclasses.dataclass(frozen=True)
class Rbf:
    """Squared-exponential kernel."""

    rho: float
    amplitude: float = 1.0

    name = "rbf"

    def variance(self) -> float:
        return self.amplitude**2

    def eval(self, d):
        r = d / self.rho
        return self.amplitude**2 * jnp.exp(-0.5 * r * r)


KERNELS = {
    "matern12": matern12,
    "matern32": matern32,
    "matern52": matern52,
    "rbf": Rbf,
}


def make_kernel(name: str, rho: float, amplitude: float = 1.0):
    return KERNELS[name](rho, amplitude)
