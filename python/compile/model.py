"""L2 — GP regression through the standardized posterior (paper Eq. 3).

With the generative view, inference needs neither `K⁻¹` nor `log|K|`:

    -log p(y, xi) = 0.5·||(y_obs - A·s(xi)) / sigma_n||²  (Gaussian likelihood)
                  + 0.5·||xi||²                           (standard prior)
                  + const,

where ``s(xi) = sqrt(K_ICR)·xi`` and ``A`` restricts to observed indices.
Evaluating the posterior costs exactly two applications of the square
root: one forward, one in the backward pass (paper §1) — which is visible
here as ``jax.value_and_grad`` of a loss that contains a single
``apply_sqrt`` call.

The AOT pipeline lowers ``loss_and_grad`` so the Rust end-to-end driver
(`examples/regression_e2e.rs`) can run the whole optimization loop without
Python.
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .icr import apply_sqrt
from .refinement import IcrModel


def make_loss(model: IcrModel, obs_idx: Optional[Sequence[int]] = None, *,
              use_pallas: bool = True):
    """Build ``loss(xi, y_obs, sigma_n)`` for a fixed observation pattern.

    ``obs_idx`` (static) selects which modeled points are observed;
    ``None`` observes every point.
    """
    idx = None if obs_idx is None else jnp.asarray(np.asarray(obs_idx, dtype=np.int64))

    def loss(xi, y_obs, sigma_n):
        s = apply_sqrt(model, xi, use_pallas=use_pallas)
        pred = s if idx is None else s[idx]
        resid = (y_obs - pred) / sigma_n
        return 0.5 * jnp.sum(resid * resid) + 0.5 * jnp.sum(xi * xi)

    return loss


def make_loss_and_grad(model: IcrModel, obs_idx: Optional[Sequence[int]] = None, *,
                       use_pallas: bool = True):
    """``(xi, y_obs, sigma_n) -> (loss, dloss/dxi)`` — the artifact the Rust
    optimizer consumes (two sqrt-applies per step, as the paper counts)."""
    return jax.value_and_grad(make_loss(model, obs_idx, use_pallas=use_pallas))


def predict(model: IcrModel, xi, *, use_pallas: bool = True):
    """Posterior-mean field for optimized excitations (MAP of Eq. 3)."""
    return apply_sqrt(model, xi, use_pallas=use_pallas)
