"""AOT pipeline: lower every model variant to HLO *text* artifacts.

This is the single point where Python runs — ``make artifacts`` invokes it
once; the Rust coordinator then loads and executes the artifacts via PJRT
with no Python on the request path.

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the offline
``xla`` crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Emitted artifacts (all f64, all with baked refinement matrices):

- ``icr_apply_<tag>``        — xi (dof,) -> s (N,): the Fig. 4 ICR forward
                               pass for each paper parametrization + size.
- ``icr_apply_batch<B>_<tag>`` — xi (B, dof) -> s (B, N): the coordinator's
                               dynamic-batching executables.
- ``kissgp_forward_n<N>``    — (y (N,), probes (10, N)) -> (x, logdet,
                               residual): the Fig. 4 baseline forward pass.
- ``icr_loss_grad_<tag>``    — (xi, y_obs, sigma) -> (loss, grad): the
                               standardized-VI objective for the Rust
                               end-to-end regression driver.

Every ICR artifact carries a validation vector (deterministic xi, expected
output head + L2 norm) so the Rust runtime can self-check after compile.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .charts import LogChart
from .cov import matern32
from .geometry import RefinementParams, build_positions
from .icr import apply_sqrt, apply_sqrt_batch
from .kissgp import build_kissgp, kissgp_forward
from .model import make_loss_and_grad
from .refinement import build_icr_model

PAPER_TARGET_N = 200
PAPER_N_LVL = 5
PAPER_PARAMS = [(3, 2), (3, 4), (5, 2), (5, 4), (5, 6)]
FIG4_SIZES = [128, 512, 2048, 8192]
BATCH_SIZES = [8, 32]
RHO = 1.0
D_MIN = 0.02  # nearest-neighbour spacing sweep: 2%·rho … rho (paper §5.1)
D_MAX = 1.0
LANCZOS_PROBES = 10


def to_hlo_text(fn, *example_args) -> str:
    """Lower a jittable function to HLO text (the interchange format)."""
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is load-bearing: the default printer elides
    # the baked refinement matrices as `constant({...})`, which parses back
    # as garbage on the Rust side.
    text = comp.as_hlo_text(print_large_constants=True)
    assert "{...}" not in text, "HLO printer elided a constant"
    return text


def paper_chart(params: RefinementParams) -> LogChart:
    """The §5 geometry: final-level grid (unit spacing) maps to points with
    nearest-neighbour distances from 2%·rho to rho."""
    positions = build_positions(params)
    final = positions[-1]
    return LogChart.from_neighbor_distances(len(final), D_MIN, D_MAX, u0=final[0])


def validation_xi(dof: int) -> np.ndarray:
    """Deterministic pseudo-excitations shared with the Rust tests."""
    return np.sin(0.37 * np.arange(dof, dtype=np.float64))


def build_icr_artifact(c: int, f: int, target_n: int, n_lvl: int):
    params = RefinementParams.for_target(c, f, n_lvl, target_n)
    chart = paper_chart(params)
    kernel = matern32(RHO)
    model = build_icr_model(kernel, chart, params)
    return params, chart, model


class Emitter:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.entries = []
        os.makedirs(out_dir, exist_ok=True)

    def emit(self, name: str, fn, example_args, inputs, outputs, meta, validation=None):
        t0 = time.time()
        text = to_hlo_text(fn, *example_args)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as fh:
            fh.write(text)
        entry = {
            "name": name,
            "file": fname,
            "inputs": inputs,
            "outputs": outputs,
            "meta": meta,
        }
        if validation is not None:
            entry["validation"] = validation
        self.entries.append(entry)
        print(f"  [{time.time() - t0:6.2f}s] {name}: {len(text) / 1e6:.2f} MB", flush=True)

    def finalize(self):
        manifest = {
            "version": 1,
            "generated_by": "python/compile/aot.py",
            "jax_version": jax.__version__,
            "dtype": "f64",
            "lanczos_probes": LANCZOS_PROBES,
            "artifacts": self.entries,
        }
        path = os.path.join(self.out_dir, "manifest.json")
        with open(path, "w") as fh:
            json.dump(manifest, fh, indent=2)
        print(f"wrote {path} ({len(self.entries)} artifacts)")


def icr_meta(params: RefinementParams, chart: LogChart, model, batch=1):
    return {
        "kind": "icr",
        "n": params.final_size(),
        "dof": params.total_dof(),
        "n_csz": params.n_csz,
        "n_fsz": params.n_fsz,
        "n_lvl": params.n_lvl,
        "n0": params.n0,
        "kernel": "matern32",
        "rho": RHO,
        "amplitude": 1.0,
        "chart": "log",
        "chart_alpha": chart.alpha,
        "chart_beta": chart.beta,
        "excitation_sizes": params.excitation_sizes(),
        "batch": batch,
        "domain_points_head": [float(x) for x in model.domain_points[:8]],
        "domain_points_l2": float(np.linalg.norm(model.domain_points)),
    }


def icr_validation(model, params) -> dict:
    xi = validation_xi(params.total_dof())
    out = np.asarray(apply_sqrt(model, jnp.asarray(xi), use_pallas=True))
    return {
        "xi": "sin(0.37*arange(dof))",
        "out_head": [float(v) for v in out[:8]],
        "out_l2": float(np.linalg.norm(out)),
    }


def emit_all(out_dir: str, quick: bool = False) -> None:
    em = Emitter(out_dir)
    kernel = matern32(RHO)

    # --- ICR apply: the five §5.1 parametrizations at N ≈ 200. ---------
    paper_params = [(5, 4)] if quick else PAPER_PARAMS
    for c, f in paper_params:
        params, chart, model = build_icr_artifact(c, f, PAPER_TARGET_N, PAPER_N_LVL)
        tag = f"c{c}f{f}_n{params.final_size()}"
        dof = params.total_dof()
        em.emit(
            f"icr_apply_{tag}",
            lambda xi, m=model: (apply_sqrt(m, xi, use_pallas=True),),
            (jax.ShapeDtypeStruct((dof,), jnp.float64),),
            inputs=[{"name": "xi", "shape": [dof], "dtype": "f64"}],
            outputs=[{"name": "s", "shape": [params.final_size()], "dtype": "f64"}],
            meta=icr_meta(params, chart, model),
            validation=icr_validation(model, params),
        )

    # --- Batched ICR apply for the coordinator's dynamic batcher. ------
    params, chart, model = build_icr_artifact(5, 4, PAPER_TARGET_N, PAPER_N_LVL)
    dof = params.total_dof()
    n = params.final_size()
    for b in [BATCH_SIZES[0]] if quick else BATCH_SIZES:
        em.emit(
            f"icr_apply_batch{b}_c5f4_n{n}",
            lambda xi, m=model: (apply_sqrt_batch(m, xi, use_pallas=False),),
            (jax.ShapeDtypeStruct((b, dof), jnp.float64),),
            inputs=[{"name": "xi", "shape": [b, dof], "dtype": "f64"}],
            outputs=[{"name": "s", "shape": [b, n], "dtype": "f64"}],
            meta=icr_meta(params, chart, model, batch=b),
        )

    # --- Fig. 4 size sweep: ICR apply + KISS-GP forward per N. ---------
    fig4_sizes = [128] if quick else FIG4_SIZES
    for target in fig4_sizes:
        params, chart, model = build_icr_artifact(3, 2, target, PAPER_N_LVL)
        n = params.final_size()
        dof = params.total_dof()
        em.emit(
            f"icr_apply_fig4_n{n}",
            lambda xi, m=model: (apply_sqrt(m, xi, use_pallas=True),),
            (jax.ShapeDtypeStruct((dof,), jnp.float64),),
            inputs=[{"name": "xi", "shape": [dof], "dtype": "f64"}],
            outputs=[{"name": "s", "shape": [n], "dtype": "f64"}],
            meta=icr_meta(params, chart, model),
            validation=icr_validation(model, params),
        )

        # KISS-GP on the same modeled points (paper: M = N, no padding for
        # the speed lane, jitter for invertibility).
        op = build_kissgp(kernel, model.domain_points, m=n, padding=0.0, jitter=1e-6)
        em.emit(
            f"kissgp_forward_n{n}",
            lambda y, probes, o=op: kissgp_forward(o, y, probes),
            (
                jax.ShapeDtypeStruct((n,), jnp.float64),
                jax.ShapeDtypeStruct((LANCZOS_PROBES, n), jnp.float64),
            ),
            inputs=[
                {"name": "y", "shape": [n], "dtype": "f64"},
                {"name": "probes", "shape": [LANCZOS_PROBES, n], "dtype": "f64"},
            ],
            outputs=[
                {"name": "x", "shape": [n], "dtype": "f64"},
                {"name": "logdet", "shape": [], "dtype": "f64"},
                {"name": "residual", "shape": [], "dtype": "f64"},
            ],
            meta={
                "kind": "kissgp",
                "n": n,
                "m": n,
                "padding": 0.0,
                "jitter": 1e-6,
                "cg_iters": 40,
                "lanczos_probes": LANCZOS_PROBES,
                "lanczos_iters": 15,
                "kernel": "matern32",
                "rho": RHO,
            },
        )

    # --- Standardized-VI loss+grad for the end-to-end driver. ----------
    params, chart, model = build_icr_artifact(5, 4, PAPER_TARGET_N, PAPER_N_LVL)
    dof = params.total_dof()
    n = params.final_size()
    obs_idx = np.arange(0, n, 2)  # observe every other point
    lg = make_loss_and_grad(model, obs_idx, use_pallas=True)
    em.emit(
        f"icr_loss_grad_c5f4_n{n}",
        lambda xi, y, sigma: lg(xi, y, sigma),
        (
            jax.ShapeDtypeStruct((dof,), jnp.float64),
            jax.ShapeDtypeStruct((len(obs_idx),), jnp.float64),
            jax.ShapeDtypeStruct((), jnp.float64),
        ),
        inputs=[
            {"name": "xi", "shape": [dof], "dtype": "f64"},
            {"name": "y_obs", "shape": [len(obs_idx)], "dtype": "f64"},
            {"name": "sigma_n", "shape": [], "dtype": "f64"},
        ],
        outputs=[
            {"name": "loss", "shape": [], "dtype": "f64"},
            {"name": "grad", "shape": [dof], "dtype": "f64"},
        ],
        meta={
            **icr_meta(params, chart, model),
            "kind": "icr_loss_grad",
            "obs_idx_stride": 2,
            "n_obs": int(len(obs_idx)),
        },
    )

    em.finalize()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact output directory")
    ap.add_argument("--quick", action="store_true", help="emit the minimal set (CI smoke)")
    args = ap.parse_args()
    t0 = time.time()
    emit_all(args.out, quick=args.quick)
    print(f"total {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
