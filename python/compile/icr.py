"""L2 — the full ICR forward pass: apply ``sqrt(K_ICR)`` (paper Alg. 1).

Chains the L1 Pallas refinement kernels over all levels. The flat
excitation layout matches the Rust engine (`rust/src/icr/engine.rs`):
``[xi_base (n0), xi_level1 (n1), ..., xi_level_nlvl (N)]``.
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from .geometry import RefinementParams
from .kernels import ref as ref_kernels
from .kernels import refine as pallas_kernels
from .refinement import IcrModel, split_excitations


def apply_sqrt(model: IcrModel, xi_flat, *, use_pallas: bool = True, block_w=None):
    """Apply ``sqrt(K_ICR)`` to a flat excitation vector → field of shape (N,).

    ``use_pallas=False`` routes through the pure-jnp oracle (``ref.py``) —
    used by the test suite to pin the Pallas path and by HLO-size ablations.
    """
    params: RefinementParams = model.params
    chunks = split_excitations(params, xi_flat)
    s = ref_kernels.base_apply_ref(model.base_sqrt, chunks[0])
    for l, lm in enumerate(model.levels):
        nw = params.n_windows(s.shape[0])
        xi_l = chunks[l + 1].reshape(nw, params.n_fsz)
        if lm.stationary:
            fn = (
                pallas_kernels.refine_stationary_pallas
                if use_pallas
                else ref_kernels.refine_stationary_ref
            )
            kwargs = {"block_w": block_w} if use_pallas else {}
            s = fn(s, lm.r, lm.sqrt_d, xi_l, params.stride, **kwargs)
        else:
            fn = (
                pallas_kernels.refine_charted_pallas
                if use_pallas
                else ref_kernels.refine_charted_ref
            )
            kwargs = {"block_w": block_w} if use_pallas else {}
            s = fn(s, lm.r, lm.sqrt_d, xi_l, params.stride, **kwargs)
    return s


def apply_sqrt_batch(model: IcrModel, xi_batch, *, use_pallas: bool = True):
    """Vectorized apply over a batch of excitations: (B, dof) → (B, N).

    The coordinator's dynamic batcher coalesces concurrent sampling
    requests into one executable call of this shape.
    """
    import jax

    return jax.vmap(lambda x: apply_sqrt(model, x, use_pallas=use_pallas))(xi_batch)


def sqrt_matrix(model: IcrModel, *, use_pallas: bool = False):
    """Materialize the (N, dof) matrix of sqrt(K_ICR) — evaluation only."""
    dof = model.params.total_dof()
    eye = jnp.eye(dof, dtype=jnp.float64)
    return apply_sqrt_batch(model, eye, use_pallas=use_pallas).T


def implicit_covariance(model: IcrModel, *, use_pallas: bool = False):
    """K_ICR = S @ S.T — the Fig. 3 object."""
    s = sqrt_matrix(model, use_pallas=use_pallas)
    k = s @ s.T
    return 0.5 * (k + k.T)


def sample(model: IcrModel, key, *, use_pallas: bool = True, batch: Optional[int] = None):
    """Draw approximate GP sample(s) with standard-normal excitations."""
    import jax

    dof = model.params.total_dof()
    if batch is None:
        xi = jax.random.normal(key, (dof,), dtype=jnp.float64)
        return apply_sqrt(model, xi, use_pallas=use_pallas)
    xi = jax.random.normal(key, (batch, dof), dtype=jnp.float64)
    return apply_sqrt_batch(model, xi, use_pallas=use_pallas)
