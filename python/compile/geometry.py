"""Refinement geometry (paper §4.2-§4.4) — mirror of ``rust/src/icr/geometry.rs``.

Each level-`l` window covers ``n_csz`` consecutive coarse pixels and emits
``n_fsz`` fine pixels at half the coarse spacing, centred on the window;
windows slide by ``n_fsz/2`` coarse pixels so the union of fine pixels is
again a regular grid at doubled resolution. ``(3, 2)`` reproduces
Algorithm 1's ``N_f = 2(N_c - 2)``.

This module is pure Python (no jax) so both the AOT pipeline and the tests
can use it without tracing.
"""

from __future__ import annotations

import dataclasses
from typing import List


@dataclasses.dataclass(frozen=True)
class RefinementParams:
    """Refinement hyper-parameters (paper §4.4 tunables)."""

    n_csz: int
    n_fsz: int
    n_lvl: int
    n0: int

    def __post_init__(self) -> None:
        if self.n_csz < 3 or self.n_csz % 2 == 0:
            raise ValueError(f"n_csz must be odd >= 3, got {self.n_csz}")
        if self.n_fsz < 2 or self.n_fsz % 2 == 1:
            raise ValueError(f"n_fsz must be even >= 2, got {self.n_fsz}")
        if self.n0 < max(self.n_csz, 3):
            raise ValueError(f"n0 = {self.n0} must be >= max(n_csz, 3)")
        sizes = self.level_sizes()
        for l, n in enumerate(sizes[1:], start=1):
            if n < 1:
                raise ValueError(f"level {l} collapses to zero pixels")
        if self.n_lvl > 0 and sizes[self.n_lvl - 1] < self.n_csz:
            raise ValueError(
                f"level {self.n_lvl - 1} has {sizes[self.n_lvl - 1]} pixels < n_csz"
            )

    @property
    def stride(self) -> int:
        """Window stride in coarse pixels (= n_fsz / 2: resolution doubles)."""
        return self.n_fsz // 2

    def n_windows(self, nc: int) -> int:
        if nc < self.n_csz:
            return 0
        return (nc - self.n_csz) // self.stride + 1

    def level_sizes(self) -> List[int]:
        sizes = [self.n0]
        n = self.n0
        for _ in range(self.n_lvl):
            n = self.n_fsz * self.n_windows(n)
            sizes.append(n)
        return sizes

    def final_size(self) -> int:
        return self.level_sizes()[-1]

    def total_dof(self) -> int:
        sizes = self.level_sizes()
        return self.n0 + sum(sizes[1:])

    def excitation_sizes(self) -> List[int]:
        return self.level_sizes()

    @staticmethod
    def for_target(n_csz: int, n_fsz: int, n_lvl: int, target: int) -> "RefinementParams":
        """Smallest base grid whose final size reaches ``target``."""
        n0 = max(n_csz, 3)
        while n0 < target * 4 + 64:
            try:
                p = RefinementParams(n_csz, n_fsz, n_lvl, n0)
            except ValueError:
                n0 += 1
                continue
            if p.final_size() >= target:
                return p
            n0 += 1
        raise ValueError(f"cannot reach target {target} with ({n_csz},{n_fsz})x{n_lvl}")

    @staticmethod
    def paper_candidates(n_lvl: int, target: int) -> List["RefinementParams"]:
        """The §5.1 candidate set {(3,2),(3,4),(5,2),(5,4),(5,6)}."""
        out = []
        for c, f in [(3, 2), (3, 4), (5, 2), (5, 4), (5, 6)]:
            try:
                out.append(RefinementParams.for_target(c, f, n_lvl, target))
            except ValueError:
                pass
        return out


def refine_positions(params: RefinementParams, coarse: List[float]) -> List[float]:
    """Fine-pixel grid coordinates from one refinement of ``coarse``."""
    csz, fsz, s = params.n_csz, params.n_fsz, params.stride
    nw = params.n_windows(len(coarse))
    fine: List[float] = []
    for w in range(nw):
        i0 = w * s
        first, last = coarse[i0], coarse[i0 + csz - 1]
        center = 0.5 * (first + last)
        dc = (last - first) / (csz - 1)
        df = 0.5 * dc
        for k in range(fsz):
            fine.append(center + (k - (fsz - 1) / 2.0) * df)
    return fine


def build_positions(params: RefinementParams) -> List[List[float]]:
    """Grid coordinates per level; base spacing 2^n_lvl → final ≈ unit."""
    d0 = float(1 << params.n_lvl)
    positions = [[i * d0 for i in range(params.n0)]]
    for _ in range(params.n_lvl):
        positions.append(refine_positions(params, positions[-1]))
    return positions
