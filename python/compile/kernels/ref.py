"""Pure-jnp oracle for the refinement step — the correctness reference the
Pallas kernels (``refine.py``) are tested against (pytest + hypothesis).

One refinement level (paper Eqs. 11-12, generalized to (n_csz, n_fsz)):

    s_f[w*fsz + k] = sum_j R[k,j] * s_c[w*stride + j]
                   + sum_m sqrtD[k,m] * xi[w, m]
"""

from __future__ import annotations

import jax.numpy as jnp


def window_indices(nw: int, csz: int, stride: int):
    """(nw, csz) gather indices of each window into the coarse vector."""
    return stride * jnp.arange(nw)[:, None] + jnp.arange(csz)[None, :]


def refine_stationary_ref(s_c, r, sqrt_d, xi, stride: int):
    """Stationary refinement: one broadcast ``(R, sqrtD)`` pair.

    s_c: (Nc,); r: (fsz, csz); sqrt_d: (fsz, fsz) lower; xi: (nw, fsz).
    Returns the fine vector of shape (nw * fsz,).
    """
    nw, fsz = xi.shape
    csz = r.shape[1]
    windows = s_c[window_indices(nw, csz, stride)]  # (nw, csz)
    interp = windows @ r.T  # (nw, fsz)
    corr = xi @ sqrt_d.T  # (nw, fsz)
    return (interp + corr).reshape(nw * fsz)


def refine_charted_ref(s_c, r_all, sqrt_d_all, xi, stride: int):
    """Charted refinement: per-window matrices.

    r_all: (nw, fsz, csz); sqrt_d_all: (nw, fsz, fsz); xi: (nw, fsz).
    """
    nw, fsz = xi.shape
    csz = r_all.shape[2]
    windows = s_c[window_indices(nw, csz, stride)]  # (nw, csz)
    interp = jnp.einsum("wkc,wc->wk", r_all, windows)
    corr = jnp.einsum("wkm,wm->wk", sqrt_d_all, xi)
    return (interp + corr).reshape(nw * fsz)


def base_apply_ref(base_sqrt, xi0):
    """Base level: dense lower-triangular apply ``s0 = L0 @ xi0``."""
    return base_sqrt @ xi0
