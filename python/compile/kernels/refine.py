"""L1 — Pallas refinement kernels (the paper's compute hot-spot).

The refinement step (paper Eqs. 11-12) is a strided stencil: every window
of ``n_csz`` coarse pixels produces ``n_fsz`` fine pixels through a small
interpolation matmul plus a lower-triangular correction matmul. The
kernels tile the *window* axis: each grid program owns ``block_w`` windows,
reads the coarse halo it needs, and fuses interpolation + correction in a
single pass so the memory traffic per level is exactly
``read s_c + read xi + write s_f``.

TPU mapping (DESIGN.md §Hardware-Adaptation): the window tile is the VMEM
working set — ``block_w·(n_csz + 2·n_fsz)`` f64 values plus the broadcast
matrices; the contractions are (n_fsz × n_csz)·(n_csz) — VPU-sized, not
MXU-sized — so the kernel is deliberately memory-bound and the right
optimization is the fusion, not MXU tiling.

Pallas runs with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and interpret-mode lowers to plain HLO that both the
pytest suite and the Rust runtime can run. Correctness vs ``ref.py`` is
enforced by ``python/tests/test_refine_pallas.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def auto_block_w(nw: int) -> int:
    """Choose the window-tile size.

    Interpret-mode Pallas materializes the *full* coarse vector once per
    grid program, so the per-level cost is O(n_blocks * N). A fixed tile
    (the old default, 8) therefore made the whole apply O(N^2/8) — visible
    as a log-log slope of ~1.7 in the Fig. 4 PJRT lane. Scaling the tile
    with the window count caps the number of programs per level at ~16,
    restoring O(N) (measured slope ~1.0; see EXPERIMENTS.md §Perf).
    """
    return max(8, min(1024, -(-nw // 16)))


def _pad_windows(arr, nw_pad: int):
    """Pad the leading window axis up to ``nw_pad``."""
    pad = nw_pad - arr.shape[0]
    if pad == 0:
        return arr
    widths = [(0, pad)] + [(0, 0)] * (arr.ndim - 1)
    return jnp.pad(arr, widths)


def _stationary_kernel(s_ref, r_ref, d_ref, xi_ref, o_ref, *, stride, csz, block_w, nw):
    """One grid program: ``block_w`` windows of the stationary refinement."""
    pid = pl.program_id(0)
    w0 = pid * block_w
    s = s_ref[...]  # full coarse vector (small; streamed once per program)
    r = r_ref[...]  # (fsz, csz)
    d = d_ref[...]  # (fsz, fsz) lower-triangular
    xi = xi_ref[...]  # (block_w, fsz) — this program's tile
    # Gather this tile's windows; clamp tail-padding reads into range.
    w_idx = w0 + jnp.arange(block_w)
    base = jnp.minimum(w_idx * stride, nw * stride)  # safe for pad windows
    idx = base[:, None] + jnp.arange(csz)[None, :]
    idx = jnp.minimum(idx, s.shape[0] - 1)
    windows = s[idx]  # (block_w, csz)
    # Fused interpolation + correction (Eqs. 11 + 12 in one pass).
    o_ref[...] = windows @ r.T + xi @ d.T


def _refine_stationary_pallas_raw(s_c, r, sqrt_d, xi, stride: int, block_w=None):
    """Stationary refinement via Pallas; mirrors ``ref.refine_stationary_ref``.

    s_c: (Nc,); r: (fsz, csz); sqrt_d: (fsz, fsz); xi: (nw, fsz) →
    fine vector (nw * fsz,).
    """
    nw, fsz = xi.shape
    csz = r.shape[1]
    block_w = auto_block_w(nw) if block_w is None else block_w
    block_w = max(1, min(block_w, nw))
    n_blocks = -(-nw // block_w)
    nw_pad = n_blocks * block_w
    xi_p = _pad_windows(xi, nw_pad)

    kernel = functools.partial(
        _stationary_kernel, stride=stride, csz=csz, block_w=block_w, nw=nw
    )
    out = pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec(s_c.shape, lambda i: (0,)),  # full coarse vector
            pl.BlockSpec(r.shape, lambda i: (0, 0)),
            pl.BlockSpec(sqrt_d.shape, lambda i: (0, 0)),
            pl.BlockSpec((block_w, fsz), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_w, fsz), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nw_pad, fsz), s_c.dtype),
        interpret=True,
    )(s_c, r, sqrt_d, xi_p)
    return out[:nw].reshape(nw * fsz)


def _charted_kernel(s_ref, r_ref, d_ref, xi_ref, o_ref, *, stride, csz, block_w, nw):
    """One grid program: ``block_w`` windows with per-window matrices."""
    pid = pl.program_id(0)
    w0 = pid * block_w
    s = s_ref[...]
    r = r_ref[...]  # (block_w, fsz, csz) — this tile's matrices
    d = d_ref[...]  # (block_w, fsz, fsz)
    xi = xi_ref[...]  # (block_w, fsz)
    w_idx = w0 + jnp.arange(block_w)
    base = jnp.minimum(w_idx * stride, nw * stride)
    idx = base[:, None] + jnp.arange(csz)[None, :]
    idx = jnp.minimum(idx, s.shape[0] - 1)
    windows = s[idx]  # (block_w, csz)
    interp = jnp.einsum("wkc,wc->wk", r, windows)
    corr = jnp.einsum("wkm,wm->wk", d, xi)
    o_ref[...] = interp + corr


def _refine_charted_pallas_raw(s_c, r_all, sqrt_d_all, xi, stride: int, block_w=None):
    """Charted refinement via Pallas; mirrors ``ref.refine_charted_ref``.

    r_all: (nw, fsz, csz); sqrt_d_all: (nw, fsz, fsz); xi: (nw, fsz).
    """
    nw, fsz = xi.shape
    csz = r_all.shape[2]
    block_w = auto_block_w(nw) if block_w is None else block_w
    block_w = max(1, min(block_w, nw))
    n_blocks = -(-nw // block_w)
    nw_pad = n_blocks * block_w
    xi_p = _pad_windows(xi, nw_pad)
    r_p = _pad_windows(r_all, nw_pad)
    d_p = _pad_windows(sqrt_d_all, nw_pad)

    kernel = functools.partial(
        _charted_kernel, stride=stride, csz=csz, block_w=block_w, nw=nw
    )
    out = pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec(s_c.shape, lambda i: (0,)),
            pl.BlockSpec((block_w, fsz, csz), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_w, fsz, fsz), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_w, fsz), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_w, fsz), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nw_pad, fsz), s_c.dtype),
        interpret=True,
    )(s_c, r_p, d_p, xi_p)
    return out[:nw].reshape(nw * fsz)


# ---------------------------------------------------------------------------
# custom_vjp wrappers.
#
# Pallas interpret-mode cannot be traced by jax.grad in this JAX version
# (`pl.program_id` has no jvp rule outside a grid context). The refinement
# is *linear* in (s_c, R, sqrtD-cols, xi) given the other inputs, so the
# exact VJP is cheap to state by hand; the forward pass stays the Pallas
# kernel, the backward is expressed in jnp (it lowers into the same fused
# HLO as the ref oracle). This is also the honest TPU story: the backward
# of a stencil is the transposed stencil.
# ---------------------------------------------------------------------------

from .ref import window_indices as _window_indices

_STATIONARY_CACHE = {}
_CHARTED_CACHE = {}


def _stationary_vjp(stride: int, block_w: int):
    key = (stride, block_w)
    if key in _STATIONARY_CACHE:
        return _STATIONARY_CACHE[key]

    @jax.custom_vjp
    def f(s_c, r, d, xi):
        return _refine_stationary_pallas_raw(s_c, r, d, xi, stride, block_w)

    def fwd(s_c, r, d, xi):
        return f(s_c, r, d, xi), (s_c, r, d, xi)

    def bwd(res, g):
        s_c, r, d, xi = res
        nw, fsz = xi.shape
        csz = r.shape[1]
        gw = g.reshape(nw, fsz)
        idx = _window_indices(nw, csz, stride)
        windows = s_c[idx]
        d_sc = jnp.zeros_like(s_c).at[idx].add(gw @ r)
        d_r = jnp.einsum("wk,wc->kc", gw, windows)
        d_d = jnp.einsum("wk,wm->km", gw, xi)
        d_xi = gw @ d
        return d_sc, d_r, d_d, d_xi

    f.defvjp(fwd, bwd)
    _STATIONARY_CACHE[key] = f
    return f


def _charted_vjp(stride: int, block_w: int):
    key = (stride, block_w)
    if key in _CHARTED_CACHE:
        return _CHARTED_CACHE[key]

    @jax.custom_vjp
    def f(s_c, r_all, d_all, xi):
        return _refine_charted_pallas_raw(s_c, r_all, d_all, xi, stride, block_w)

    def fwd(s_c, r_all, d_all, xi):
        return f(s_c, r_all, d_all, xi), (s_c, r_all, d_all, xi)

    def bwd(res, g):
        s_c, r_all, d_all, xi = res
        nw, fsz = xi.shape
        csz = r_all.shape[2]
        gw = g.reshape(nw, fsz)
        idx = _window_indices(nw, csz, stride)
        windows = s_c[idx]
        d_sc = jnp.zeros_like(s_c).at[idx].add(jnp.einsum("wk,wkc->wc", gw, r_all))
        d_r = jnp.einsum("wk,wc->wkc", gw, windows)
        d_d = jnp.einsum("wk,wm->wkm", gw, xi)
        d_xi = jnp.einsum("wk,wkm->wm", gw, d_all)
        return d_sc, d_r, d_d, d_xi

    f.defvjp(fwd, bwd)
    _CHARTED_CACHE[key] = f
    return f


def refine_stationary_pallas(s_c, r, sqrt_d, xi, stride: int, block_w=None):
    """Differentiable stationary Pallas refinement (see module docstring)."""
    bw = auto_block_w(xi.shape[0]) if block_w is None else block_w
    return _stationary_vjp(stride, bw)(s_c, r, sqrt_d, xi)


def refine_charted_pallas(s_c, r_all, sqrt_d_all, xi, stride: int, block_w=None):
    """Differentiable charted Pallas refinement (see module docstring)."""
    bw = auto_block_w(xi.shape[0]) if block_w is None else block_w
    return _charted_vjp(stride, bw)(s_c, r_all, sqrt_d_all, xi)
