"""AOT pipeline smoke: quick-mode emission produces loadable HLO text and a
well-formed manifest."""

import json
import os

import pytest

from compile import aot


@pytest.fixture(scope="module")
def quick_artifacts(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    aot.emit_all(out, quick=True)
    return out


def test_manifest_well_formed(quick_artifacts):
    with open(os.path.join(quick_artifacts, "manifest.json")) as fh:
        m = json.load(fh)
    assert m["version"] == 1
    assert m["dtype"] == "f64"
    assert len(m["artifacts"]) >= 4
    names = [a["name"] for a in m["artifacts"]]
    assert any(n.startswith("icr_apply_c5f4") for n in names)
    assert any(n.startswith("kissgp_forward") for n in names)
    assert any(n.startswith("icr_loss_grad") for n in names)
    for a in m["artifacts"]:
        assert os.path.exists(os.path.join(quick_artifacts, a["file"])), a["file"]
        assert a["inputs"] and a["outputs"]


def test_hlo_text_is_hlo(quick_artifacts):
    with open(os.path.join(quick_artifacts, "manifest.json")) as fh:
        m = json.load(fh)
    for a in m["artifacts"]:
        with open(os.path.join(quick_artifacts, a["file"])) as fh:
            head = fh.read(4096)
        assert head.startswith("HloModule"), a["name"]
        assert "ENTRY" in head or "ENTRY" in open(os.path.join(quick_artifacts, a["file"])).read()


def test_validation_vectors_present_and_finite(quick_artifacts):
    with open(os.path.join(quick_artifacts, "manifest.json")) as fh:
        m = json.load(fh)
    icr = [a for a in m["artifacts"] if a["name"].startswith("icr_apply_c")]
    assert icr
    for a in icr:
        v = a["validation"]
        assert len(v["out_head"]) == 8
        assert all(abs(x) < 1e6 for x in v["out_head"])
        assert v["out_l2"] > 0


def test_icr_meta_consistency(quick_artifacts):
    with open(os.path.join(quick_artifacts, "manifest.json")) as fh:
        m = json.load(fh)
    for a in m["artifacts"]:
        meta = a["meta"]
        if meta.get("kind") == "icr":
            assert sum(meta["excitation_sizes"]) == meta["dof"]
            assert meta["excitation_sizes"][-1] == meta["n"]
            if meta["batch"] == 1 and a["name"].startswith("icr_apply"):
                assert a["inputs"][0]["shape"] == [meta["dof"]]
                assert a["outputs"][0]["shape"] == [meta["n"]]
