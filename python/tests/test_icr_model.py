"""L2 integration: full ICR model properties (paper §5.1 claims)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile.charts import IdentityChart, LogChart
from compile.cov import matern32
from compile.geometry import RefinementParams, build_positions
from compile.icr import apply_sqrt, apply_sqrt_batch, implicit_covariance
from compile.refinement import build_icr_model


def true_cov(kernel, pts):
    pts = jnp.asarray(pts)
    return np.asarray(kernel.eval(jnp.abs(pts[:, None] - pts[None, :])))


def test_apply_is_linear():
    p = RefinementParams(3, 2, 2, 6)
    model = build_icr_model(matern32(3.0), IdentityChart(), p)
    rng = np.random.default_rng(1)
    a = rng.standard_normal(p.total_dof())
    b = rng.standard_normal(p.total_dof())
    fa = np.asarray(apply_sqrt(model, jnp.asarray(a)))
    fb = np.asarray(apply_sqrt(model, jnp.asarray(b)))
    combo = np.asarray(apply_sqrt(model, jnp.asarray(2.0 * a - 0.5 * b)))
    np.testing.assert_allclose(combo, 2.0 * fa - 0.5 * fb, atol=1e-11)


def test_implicit_covariance_tracks_truth_regular_grid():
    p = RefinementParams(3, 2, 3, 10)
    kernel = matern32(8.0)
    model = build_icr_model(kernel, IdentityChart(), p)
    k_icr = np.asarray(implicit_covariance(model))
    k_true = true_cov(kernel, model.domain_points)
    mae = np.abs(k_icr - k_true).mean()
    assert mae < 0.02, mae


def test_implicit_covariance_full_rank():
    # §5.2: K_ICR = sqrt·sqrtᵀ is PSD and full rank by construction.
    p = RefinementParams(3, 2, 2, 8)
    model = build_icr_model(matern32(4.0), IdentityChart(), p)
    k = np.asarray(implicit_covariance(model))
    ev = np.linalg.eigvalsh(k)
    assert ev.min() > 1e-10 * ev.max()


def test_log_chart_paper_setting_small():
    # Miniature §5.1: log-spaced points with nn distances 2%·rho → rho.
    p = RefinementParams.for_target(5, 4, 3, 48)
    pos = build_positions(p)
    chart = LogChart.from_neighbor_distances(len(pos[-1]), 0.02, 1.0, u0=pos[-1][0])
    kernel = matern32(1.0)
    model = build_icr_model(kernel, chart, p)
    # nn-distance sweep spans two orders of magnitude.
    d = np.diff(model.domain_points)
    assert d.max() / d.min() > 25.0
    k_icr = np.asarray(implicit_covariance(model))
    k_true = true_cov(kernel, model.domain_points)
    mae = np.abs(k_icr - k_true).mean()
    assert mae < 0.05, mae
    ev = np.linalg.eigvalsh(k_icr)
    assert ev.min() > 0.0


def test_batch_apply_matches_loop():
    p = RefinementParams(3, 2, 2, 8)
    model = build_icr_model(matern32(4.0), IdentityChart(), p)
    rng = np.random.default_rng(7)
    xi = rng.standard_normal((5, p.total_dof()))
    batched = np.asarray(apply_sqrt_batch(model, jnp.asarray(xi)))
    for i in range(5):
        single = np.asarray(apply_sqrt(model, jnp.asarray(xi[i])))
        np.testing.assert_allclose(batched[i], single, atol=1e-12)


def test_pallas_and_ref_paths_agree_end_to_end():
    p = RefinementParams.for_target(5, 4, 3, 40)
    pos = build_positions(p)
    chart = LogChart.from_neighbor_distances(len(pos[-1]), 0.05, 1.0, u0=pos[-1][0])
    model = build_icr_model(matern32(1.0), chart, p)
    xi = np.sin(0.37 * np.arange(p.total_dof()))
    a = np.asarray(apply_sqrt(model, jnp.asarray(xi), use_pallas=True))
    b = np.asarray(apply_sqrt(model, jnp.asarray(xi), use_pallas=False))
    np.testing.assert_allclose(a, b, atol=1e-12)


def test_sample_moments():
    p = RefinementParams(3, 2, 2, 8)
    model = build_icr_model(matern32(4.0), IdentityChart(), p)
    k = np.asarray(implicit_covariance(model))
    keys = jax.random.split(jax.random.PRNGKey(0), 1)
    xi = jax.random.normal(keys[0], (4000, p.total_dof()), dtype=jnp.float64)
    s = np.asarray(apply_sqrt_batch(model, xi, use_pallas=False))
    emp = s.T @ s / s.shape[0]
    assert np.abs(np.diag(emp) - np.diag(k)).max() < 0.1
