"""Standardized-VI loss + gradient (paper Eq. 3) checks."""

import jax.numpy as jnp
import numpy as np

from compile.charts import IdentityChart
from compile.cov import matern32
from compile.geometry import RefinementParams
from compile.icr import apply_sqrt
from compile.model import make_loss, make_loss_and_grad
from compile.refinement import build_icr_model


def small_model():
    p = RefinementParams(3, 2, 2, 6)
    return p, build_icr_model(matern32(3.0), IdentityChart(), p)


def test_loss_matches_hand_formula():
    p, model = small_model()
    rng = np.random.default_rng(0)
    xi = rng.standard_normal(p.total_dof())
    y = rng.standard_normal(p.final_size())
    sigma = 0.3
    loss = make_loss(model)(jnp.asarray(xi), jnp.asarray(y), jnp.asarray(sigma))
    s = np.asarray(apply_sqrt(model, jnp.asarray(xi)))
    want = 0.5 * np.sum(((y - s) / sigma) ** 2) + 0.5 * np.sum(xi**2)
    assert abs(float(loss) - want) < 1e-9


def test_observed_subset():
    p, model = small_model()
    obs = np.arange(0, p.final_size(), 2)
    rng = np.random.default_rng(1)
    xi = rng.standard_normal(p.total_dof())
    y = rng.standard_normal(len(obs))
    loss = make_loss(model, obs)(jnp.asarray(xi), jnp.asarray(y), jnp.asarray(0.5))
    s = np.asarray(apply_sqrt(model, jnp.asarray(xi)))[obs]
    want = 0.5 * np.sum(((y - s) / 0.5) ** 2) + 0.5 * np.sum(xi**2)
    assert abs(float(loss) - want) < 1e-9


def test_grad_matches_finite_differences():
    p, model = small_model()
    obs = np.arange(0, p.final_size(), 2)
    lg = make_loss_and_grad(model, obs)
    loss_fn = make_loss(model, obs)
    rng = np.random.default_rng(2)
    xi = rng.standard_normal(p.total_dof())
    y = rng.standard_normal(len(obs))
    sigma = jnp.asarray(0.4)
    val, grad = lg(jnp.asarray(xi), jnp.asarray(y), sigma)
    grad = np.asarray(grad)
    eps = 1e-6
    for i in [0, 5, p.total_dof() - 1]:
        xp, xm = xi.copy(), xi.copy()
        xp[i] += eps
        xm[i] -= eps
        fd = (float(loss_fn(jnp.asarray(xp), jnp.asarray(y), sigma))
              - float(loss_fn(jnp.asarray(xm), jnp.asarray(y), sigma))) / (2 * eps)
        assert abs(grad[i] - fd) < 1e-4, (i, grad[i], fd)


def test_adam_on_standardized_objective_converges():
    # Adam on the standardized objective must descend by orders of
    # magnitude — the end-to-end Rust driver (examples/regression_e2e.rs)
    # runs exactly this loop via the AOT'd loss_grad artifact.
    import jax

    p, model = small_model()
    lg = jax.jit(make_loss_and_grad(model))
    rng = np.random.default_rng(3)
    # Data from a ground-truth draw + noise.
    xi_true = rng.standard_normal(p.total_dof())
    y = np.asarray(apply_sqrt(model, jnp.asarray(xi_true))) + 0.05 * rng.standard_normal(p.final_size())
    xi = np.zeros(p.total_dof())
    m = np.zeros_like(xi)
    v = np.zeros_like(xi)
    lr, b1, b2, eps = 0.1, 0.9, 0.999, 1e-8
    sigma = jnp.asarray(0.05)
    losses = []
    for t in range(1, 151):
        val, grad = lg(jnp.asarray(xi), jnp.asarray(y), sigma)
        g = np.asarray(grad)
        losses.append(float(val))
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        xi = xi - lr * (m / (1 - b1**t)) / (np.sqrt(v / (1 - b2**t)) + eps)
    assert losses[-1] < 0.02 * losses[0], losses[::30]
