"""Shared fixtures: force f64 (the paper benchmarks in double precision)."""

import jax

jax.config.update("jax_enable_x64", True)
