"""Geometry tests — must agree with rust/src/icr/geometry.rs bit-for-bit."""

import pytest

from compile.geometry import RefinementParams, build_positions, refine_positions


def test_classic_32_growth_matches_paper():
    # Paper §4.2: N_f = 2 (N_c - 2).
    p = RefinementParams(3, 2, 5, 10)
    assert p.level_sizes() == [10, 16, 28, 52, 100, 196]


def test_five_four_reaches_exactly_200():
    p = RefinementParams(5, 4, 5, 13)
    assert p.final_size() == 200
    assert p.total_dof() == 425


def test_for_target_minimal_base():
    for c, f in [(3, 2), (3, 4), (5, 2), (5, 4), (5, 6)]:
        p = RefinementParams.for_target(c, f, 5, 200)
        assert p.final_size() >= 200
        if p.n0 > max(c, 3):
            try:
                smaller = RefinementParams(c, f, 5, p.n0 - 1)
                assert smaller.final_size() < 200
            except ValueError:
                pass


def test_paper_candidates_all_exist():
    cands = RefinementParams.paper_candidates(5, 200)
    assert len(cands) == 5
    assert {(p.n_csz, p.n_fsz) for p in cands} == {(3, 2), (3, 4), (5, 2), (5, 4), (5, 6)}


@pytest.mark.parametrize(
    "c,f,lvl,n0",
    [(2, 2, 1, 8), (3, 3, 1, 8), (5, 2, 1, 4), (3, 2, 10, 3)],
)
def test_validation_rejects_bad_params(c, f, lvl, n0):
    with pytest.raises(ValueError):
        RefinementParams(c, f, lvl, n0)


@pytest.mark.parametrize("c,f", [(3, 2), (3, 4), (5, 2), (5, 4), (5, 6)])
def test_fine_grid_uniform_half_spacing(c, f):
    p = RefinementParams(c, f, 1, 16)
    pos = build_positions(p)
    fine = pos[-1]
    assert len(fine) == p.final_size()
    d0 = float(1 << p.n_lvl)
    for a, b in zip(fine, fine[1:]):
        assert abs((b - a) - d0 / 2) < 1e-9


def test_final_level_unit_spacing():
    p = RefinementParams(3, 2, 4, 8)
    fine = build_positions(p)[-1]
    for a, b in zip(fine, fine[1:]):
        assert abs(b - a - 1.0) < 1e-9


def test_fine_pixels_at_quarter_offsets():
    # (3,2): fine pixels at coarse-center ± Δc/4 (paper Fig. 1).
    p = RefinementParams(3, 2, 1, 5)
    pos = build_positions(p)
    coarse, fine = pos[0], pos[1]
    dc = coarse[1] - coarse[0]
    assert abs(fine[0] - (coarse[1] - dc / 4)) < 1e-12
    assert abs(fine[1] - (coarse[1] + dc / 4)) < 1e-12


def test_refine_positions_nested_in_window():
    p = RefinementParams(5, 4, 1, 16)
    coarse = build_positions(p)[0]
    fine = refine_positions(p, coarse)
    s = p.stride
    for w in range(p.n_windows(len(coarse))):
        lo, hi = coarse[w * s], coarse[w * s + p.n_csz - 1]
        for k in range(p.n_fsz):
            assert lo < fine[w * p.n_fsz + k] < hi
