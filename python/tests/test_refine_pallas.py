"""L1 correctness: Pallas refinement kernels vs the pure-jnp oracle.

This is the CORE correctness signal of the compile path — hypothesis
sweeps window counts, (n_csz, n_fsz) shapes, block sizes and dtypes, and
asserts allclose against ``ref.py``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels.ref import refine_charted_ref, refine_stationary_ref
from compile.kernels.refine import refine_charted_pallas, refine_stationary_pallas

SHAPES = [(3, 2), (3, 4), (5, 2), (5, 4), (5, 6)]


def _random_case(rng, csz, fsz, nw, dtype):
    stride = fsz // 2
    nc = (nw - 1) * stride + csz
    s_c = rng.standard_normal(nc).astype(dtype)
    r = rng.standard_normal((fsz, csz)).astype(dtype)
    d = np.tril(rng.standard_normal((fsz, fsz))).astype(dtype)
    xi = rng.standard_normal((nw, fsz)).astype(dtype)
    return s_c, r, d, xi, stride


@pytest.mark.parametrize("csz,fsz", SHAPES)
@pytest.mark.parametrize("nw", [1, 2, 7, 16])
def test_stationary_matches_ref(csz, fsz, nw):
    rng = np.random.default_rng(csz * 100 + fsz * 10 + nw)
    s_c, r, d, xi, stride = _random_case(rng, csz, fsz, nw, np.float64)
    want = refine_stationary_ref(jnp.asarray(s_c), jnp.asarray(r), jnp.asarray(d), jnp.asarray(xi), stride)
    got = refine_stationary_pallas(jnp.asarray(s_c), jnp.asarray(r), jnp.asarray(d), jnp.asarray(xi), stride)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("csz,fsz", SHAPES)
@pytest.mark.parametrize("nw", [1, 3, 8, 13])
def test_charted_matches_ref(csz, fsz, nw):
    rng = np.random.default_rng(csz * 1000 + fsz * 100 + nw)
    stride = fsz // 2
    nc = (nw - 1) * stride + csz
    s_c = rng.standard_normal(nc)
    r_all = rng.standard_normal((nw, fsz, csz))
    d_all = np.tril(rng.standard_normal((nw, fsz, fsz)))
    xi = rng.standard_normal((nw, fsz))
    want = refine_charted_ref(jnp.asarray(s_c), jnp.asarray(r_all), jnp.asarray(d_all), jnp.asarray(xi), stride)
    got = refine_charted_pallas(jnp.asarray(s_c), jnp.asarray(r_all), jnp.asarray(d_all), jnp.asarray(xi), stride)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-12, atol=1e-12)


@settings(max_examples=40, deadline=None)
@given(
    shape=st.sampled_from(SHAPES),
    nw=st.integers(min_value=1, max_value=40),
    block_w=st.integers(min_value=1, max_value=16),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_stationary_hypothesis_sweep(shape, nw, block_w, seed):
    csz, fsz = shape
    rng = np.random.default_rng(seed)
    s_c, r, d, xi, stride = _random_case(rng, csz, fsz, nw, np.float64)
    want = refine_stationary_ref(jnp.asarray(s_c), jnp.asarray(r), jnp.asarray(d), jnp.asarray(xi), stride)
    got = refine_stationary_pallas(
        jnp.asarray(s_c), jnp.asarray(r), jnp.asarray(d), jnp.asarray(xi), stride, block_w=block_w
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-12, atol=1e-12)


@settings(max_examples=25, deadline=None)
@given(
    shape=st.sampled_from(SHAPES),
    nw=st.integers(min_value=1, max_value=24),
    block_w=st.integers(min_value=1, max_value=9),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_charted_hypothesis_sweep(shape, nw, block_w, seed):
    csz, fsz = shape
    stride = fsz // 2
    rng = np.random.default_rng(seed)
    nc = (nw - 1) * stride + csz
    s_c = rng.standard_normal(nc)
    r_all = rng.standard_normal((nw, fsz, csz))
    d_all = np.tril(rng.standard_normal((nw, fsz, fsz)))
    xi = rng.standard_normal((nw, fsz))
    want = refine_charted_ref(jnp.asarray(s_c), jnp.asarray(r_all), jnp.asarray(d_all), jnp.asarray(xi), stride)
    got = refine_charted_pallas(
        jnp.asarray(s_c), jnp.asarray(r_all), jnp.asarray(d_all), jnp.asarray(xi), stride, block_w=block_w
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("dtype,tol", [(np.float32, 1e-5), (np.float64, 1e-12)])
def test_dtype_sweep(dtype, tol):
    rng = np.random.default_rng(5)
    s_c, r, d, xi, stride = _random_case(rng, 3, 2, 9, dtype)
    want = refine_stationary_ref(jnp.asarray(s_c), jnp.asarray(r), jnp.asarray(d), jnp.asarray(xi), stride)
    got = refine_stationary_pallas(jnp.asarray(s_c), jnp.asarray(r), jnp.asarray(d), jnp.asarray(xi), stride)
    assert got.dtype == want.dtype
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol)


def test_pallas_kernel_is_jittable_and_gradable():
    # The loss_grad artifact differentiates through the Pallas call.
    rng = np.random.default_rng(11)
    s_c, r, d, xi, stride = _random_case(rng, 3, 2, 6, np.float64)

    def f(s):
        out = refine_stationary_pallas(s, jnp.asarray(r), jnp.asarray(d), jnp.asarray(xi), stride)
        return jnp.sum(out**2)

    g = jax.grad(f)(jnp.asarray(s_c))
    # Finite-difference check on a few coordinates.
    eps = 1e-6
    for i in [0, 3, len(s_c) - 1]:
        sp = s_c.copy()
        sp[i] += eps
        sm = s_c.copy()
        sm[i] -= eps
        fd = (f(jnp.asarray(sp)) - f(jnp.asarray(sm))) / (2 * eps)
        assert abs(float(g[i]) - float(fd)) < 1e-4
