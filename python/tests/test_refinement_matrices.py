"""Refinement-matrix construction (Eqs. 5-9) vs a numpy dense oracle."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.charts import IdentityChart, LogChart
from compile.cov import matern12, matern32, matern52
from compile.geometry import RefinementParams
from compile.refinement import build_icr_model, split_excitations, window_matrices


def dense_rd(kernel, xc, xf):
    kcc = np.asarray(kernel.eval(jnp.abs(jnp.asarray(xc)[:, None] - jnp.asarray(xc)[None, :])))
    kfc = np.asarray(kernel.eval(jnp.abs(jnp.asarray(xf)[:, None] - jnp.asarray(xc)[None, :])))
    kff = np.asarray(kernel.eval(jnp.abs(jnp.asarray(xf)[:, None] - jnp.asarray(xf)[None, :])))
    r = kfc @ np.linalg.inv(kcc)
    d = kff - r @ kfc.T
    return r, d


@pytest.mark.parametrize("kernel", [matern12(1.3), matern32(2.0), matern52(0.8)])
def test_window_matrices_match_dense_identity_chart(kernel):
    coarse = np.array([0.0, 1.0, 2.0])
    fine = np.array([0.75, 1.25])
    r, sd = window_matrices(kernel, IdentityChart(), coarse, fine)
    r_want, d_want = dense_rd(kernel, coarse, fine)
    np.testing.assert_allclose(np.asarray(r), r_want, atol=1e-9)
    np.testing.assert_allclose(np.asarray(sd) @ np.asarray(sd).T, d_want, atol=1e-9)


def test_window_matrices_log_chart():
    kernel = matern32(1.0)
    chart = LogChart(alpha=-2.0, beta=0.08)
    coarse = np.array([10.0, 14.0, 18.0, 22.0, 26.0])
    fine = np.array([16.0, 17.0, 19.0, 20.0])
    r, sd = window_matrices(kernel, chart, coarse, fine)
    xc = np.exp(chart.alpha + chart.beta * coarse)
    xf = np.exp(chart.alpha + chart.beta * fine)
    r_want, d_want = dense_rd(kernel, xc, xf)
    np.testing.assert_allclose(np.asarray(r), r_want, atol=1e-8)
    np.testing.assert_allclose(np.asarray(sd) @ np.asarray(sd).T, d_want, atol=1e-8)


def test_sqrt_d_lower_triangular():
    _, sd = window_matrices(
        matern32(1.5), IdentityChart(), np.arange(5.0), np.array([1.6, 1.9, 2.1, 2.4])
    )
    sd = np.asarray(sd)
    assert np.allclose(sd, np.tril(sd))


def test_base_sqrt_reproduces_base_covariance():
    p = RefinementParams(3, 2, 2, 8)
    kernel = matern32(4.0)
    model = build_icr_model(kernel, IdentityChart(), p)
    l0 = np.asarray(model.base_sqrt)
    base_u = model.positions[0]
    k0 = np.asarray(kernel.eval(jnp.abs(jnp.asarray(base_u)[:, None] - jnp.asarray(base_u)[None, :])))
    np.testing.assert_allclose(l0 @ l0.T, k0, atol=1e-8)


def test_stationary_vs_charted_levels():
    p = RefinementParams(3, 2, 2, 8)
    kernel = matern32(4.0)
    m_affine = build_icr_model(kernel, IdentityChart(), p)
    assert all(lv.stationary for lv in m_affine.levels)
    assert m_affine.levels[0].r.ndim == 2

    m_log = build_icr_model(kernel, LogChart(alpha=0.0, beta=0.02), p)
    assert all(not lv.stationary for lv in m_log.levels)
    assert m_log.levels[0].r.ndim == 3
    assert m_log.levels[0].r.shape[0] == p.n_windows(p.n0)


def test_split_excitations_layout():
    p = RefinementParams(3, 2, 3, 10)
    xi = np.arange(p.total_dof(), dtype=np.float64)
    chunks = split_excitations(p, jnp.asarray(xi))
    sizes = p.excitation_sizes()
    assert [c.shape[0] for c in chunks] == sizes
    # Flat layout: base first, then levels in order.
    assert float(chunks[0][0]) == 0.0
    assert float(chunks[1][0]) == float(sizes[0])
