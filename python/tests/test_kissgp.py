"""KISS-GP (JAX lane) tests vs dense oracles."""

import jax.numpy as jnp
import numpy as np

from compile.cov import matern32
from compile.kissgp import apply_k, build_kissgp, cg_solve, kissgp_forward, lanczos_logdet


def dense_kiss(kernel, pts, m, padding, jitter):
    pts = np.asarray(pts)
    lo, hi = pts.min(), pts.max()
    spacing = (hi - lo) / (m - 1)
    grid = lo + spacing * np.arange(m)
    t = np.clip((pts - lo) / spacing, 0, m - 1)
    idx = np.minimum(np.floor(t).astype(int), m - 2)
    wl = 1.0 - (t - idx)
    w = np.zeros((len(pts), m))
    w[np.arange(len(pts)), idx] = wl
    w[np.arange(len(pts)), idx + 1] = 1.0 - wl
    kuu = np.asarray(matern32(kernel.rho).eval(jnp.abs(jnp.asarray(grid)[:, None] - jnp.asarray(grid)[None, :])))
    return w @ kuu @ w.T + jitter * np.eye(len(pts))


def test_apply_matches_dense_with_full_padding():
    kernel = matern32(1.0)
    pts = np.arange(24) * 0.35
    op = build_kissgp(kernel, pts, m=24, padding=1.0, jitter=1e-4)
    dense = dense_kiss(kernel, pts, 24, 1.0, 1e-4)
    rng = np.random.default_rng(3)
    v = rng.standard_normal(24)
    got = np.asarray(apply_k(op, jnp.asarray(v)))
    np.testing.assert_allclose(got, dense @ v, atol=1e-9)


def test_cg_solves_jittered_system():
    kernel = matern32(1.0)
    pts = np.arange(48) * 0.3
    op = build_kissgp(kernel, pts, m=48, padding=1.0, jitter=1e-2)
    rng = np.random.default_rng(5)
    y = rng.standard_normal(48)
    x, res = cg_solve(op, jnp.asarray(y), 200)
    kx = np.asarray(apply_k(op, x))
    assert np.linalg.norm(kx - y) < 1e-6 * np.linalg.norm(y), float(res)


def test_lanczos_logdet_close_to_dense():
    kernel = matern32(1.0)
    pts = np.arange(64) * 0.4
    op = build_kissgp(kernel, pts, m=64, padding=1.0, jitter=1e-3)
    dense = dense_kiss(kernel, pts, 64, 1.0, 1e-3)
    exact = np.linalg.slogdet(dense)[1]
    rng = np.random.default_rng(11)
    probes = rng.choice([-1.0, 1.0], size=(10, 64))
    est = float(lanczos_logdet(op, jnp.asarray(probes), 15))
    assert abs(est - exact) / abs(exact) < 0.1, (est, exact)


def test_forward_pass_outputs():
    kernel = matern32(1.0)
    pts = np.arange(32) * 0.5
    op = build_kissgp(kernel, pts, m=32, padding=0.0, jitter=1e-3)
    rng = np.random.default_rng(2)
    y = rng.standard_normal(32)
    probes = rng.choice([-1.0, 1.0], size=(10, 32))
    x, logdet, res = kissgp_forward(op, jnp.asarray(y), jnp.asarray(probes))
    assert x.shape == (32,)
    assert np.isfinite(float(logdet))
    assert float(res) >= 0.0
    # CG(40) should have made real progress on a jittered SPD system.
    kx = np.asarray(apply_k(op, x))
    assert np.linalg.norm(kx - y) < 0.1 * np.linalg.norm(y)
