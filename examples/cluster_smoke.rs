//! Cluster-smoke: the CI leg for the multi-node cluster subsystem
//! (`DESIGN.md` §9).
//!
//! Spawns TWO backend `icr serve`-equivalents on ephemeral tcp ports,
//! then one front-door coordinator whose `gp` replica set mixes a local
//! native member with both remote backends, with the response cache
//! enabled. Drives mixed v1/v2 traffic from concurrent clients over the
//! front door's unix socket, then asserts:
//!
//! - cross-node routing: every backend coordinator served requests;
//! - byte determinism: each sampled seed matches the single-node engine;
//! - cache: repeated (seed, count) frames hit (hit counter > 0) and the
//!   cached reply line is byte-identical to the fresh one;
//! - health: both remote members are reported `healthy` with their tcp
//!   endpoints in the `cluster` stats section.
//!
//! Exits non-zero on any violation.
//!
//! ```text
//! cargo run --release --example cluster_smoke
//! ```

use std::io::{BufRead, BufReader, Write};
use std::os::unix::net::UnixStream;
use std::sync::atomic::Ordering;
use std::sync::Arc;

use icr::config::{Backend, MemberSpec, ModelConfig, ReplicaSpec, ServerConfig};
use icr::coordinator::Coordinator;
use icr::json::Value;
use icr::model::GpModel;
use icr::net::{ListenAddr, NetServer};

fn small_model() -> ModelConfig {
    ModelConfig { n_csz: 3, n_fsz: 2, n_lvl: 3, target_n: 48, ..ModelConfig::default() }
}

struct Node {
    addr: String,
    coord: Arc<Coordinator>,
    stop: Arc<std::sync::atomic::AtomicBool>,
    handle: std::thread::JoinHandle<anyhow::Result<()>>,
}

fn start_backend() -> Node {
    let cfg = ServerConfig {
        model: small_model(),
        workers: 2,
        max_batch: 8,
        max_wait_us: 500,
        idle_timeout_ms: 0,
        listen: ListenAddr::Tcp("127.0.0.1:0".into()),
        ..ServerConfig::default()
    };
    let coord = Arc::new(Coordinator::start(cfg.clone()).expect("backend coordinator"));
    let server = NetServer::bind(&cfg, coord.clone()).expect("bind backend");
    let addr = server.local_addr().strip_prefix("tcp:").expect("tcp addr").to_string();
    let stop = server.shutdown_handle();
    let handle = std::thread::spawn(move || server.run());
    Node { addr, coord, stop, handle }
}

fn rpc(reader: &mut BufReader<UnixStream>, writer: &mut UnixStream, line: &str) -> (String, Value) {
    writeln!(writer, "{line}").expect("send");
    writer.flush().expect("flush");
    let mut resp = String::new();
    let n = reader.read_line(&mut resp).expect("recv");
    assert!(n > 0, "server hung up mid-request");
    resp.truncate(resp.trim_end().len());
    let v = Value::parse(&resp).unwrap_or_else(|e| panic!("bad frame {resp:?}: {e}"));
    (resp, v)
}

fn main() {
    // Two shards…
    let b1 = start_backend();
    let b2 = start_backend();
    println!("cluster-smoke: backends on tcp:{} and tcp:{}", b1.addr, b2.addr);

    // …one front door: local native member + both remotes, cache on.
    let sock = std::env::temp_dir().join(format!("icr_cluster_smoke_{}.sock", std::process::id()));
    let members = vec![
        MemberSpec::local(Backend::Native),
        MemberSpec::remote(&format!("tcp:{}", b1.addr)).expect("remote member 1"),
        MemberSpec::remote(&format!("tcp:{}", b2.addr)).expect("remote member 2"),
    ];
    let cfg = ServerConfig {
        model: small_model(),
        workers: 2,
        max_batch: 8,
        max_wait_us: 1000,
        idle_timeout_ms: 0,
        listen: ListenAddr::Unix(sock.clone()),
        replicas: vec![ReplicaSpec::new("gp", members).expect("replica spec")],
        cache_entries: 32,
        health_interval_ms: 500,
        ..ServerConfig::default()
    };
    let front = Arc::new(Coordinator::start(cfg.clone()).expect("front door"));
    let server = NetServer::bind(&cfg, front.clone()).expect("bind front door");
    println!("cluster-smoke: front door on {}", server.local_addr());
    let stop = server.shutdown_handle();
    let handle = std::thread::spawn(move || server.run());

    let engine = front.engine().clone();

    // 4 concurrent clients × 16 seeded samples through the replica set,
    // every reply byte-checked against the single-node engine.
    std::thread::scope(|sc| {
        for t in 0..4u64 {
            let sock = sock.clone();
            let engine = engine.clone();
            sc.spawn(move || {
                let stream = UnixStream::connect(&sock).expect("connect");
                let mut reader = BufReader::new(stream.try_clone().expect("clone"));
                let mut writer = stream;
                for i in 0..16u64 {
                    let seed = t * 100 + i;
                    let want = engine.sample(1, seed).expect("engine sample").remove(0);
                    let (_, v) = if i % 2 == 0 {
                        rpc(
                            &mut reader,
                            &mut writer,
                            &format!(
                                r#"{{"v": 2, "op": "sample", "model": "gp", "id": {i}, "count": 1, "seed": {seed}}}"#
                            ),
                        )
                    } else {
                        // v1 untagged → default model, same bytes.
                        rpc(
                            &mut reader,
                            &mut writer,
                            &format!(r#"{{"op": "sample", "count": 1, "seed": {seed}}}"#),
                        )
                    };
                    let payload = v.get("result").unwrap_or(&v);
                    let got: Vec<f64> = payload
                        .get("samples")
                        .and_then(Value::as_array)
                        .expect("samples")[0]
                        .as_array()
                        .expect("row")
                        .iter()
                        .filter_map(Value::as_f64)
                        .collect();
                    assert_eq!(got, want, "client {t} seed {seed} diverged from single-node");
                }
            });
        }
    });

    // Cache: the same frame twice must hit and be byte-identical.
    let stream = UnixStream::connect(&sock).expect("connect");
    let mut reader = BufReader::new(stream.try_clone().expect("clone"));
    let mut writer = stream;
    let frame = r#"{"v": 2, "op": "sample", "model": "gp", "id": 77, "count": 2, "seed": 4242}"#;
    let (fresh, _) = rpc(&mut reader, &mut writer, frame);
    let (cached, _) = rpc(&mut reader, &mut writer, frame);
    assert_eq!(cached, fresh, "cached reply not byte-identical");
    assert!(front.cache().hits() >= 1, "cache never hit");

    // Cross-node routing: each backend actually executed sample applies
    // for front-door traffic. (requests_submitted would be vacuous — the
    // front door's own describe + health probes bump it; applies only
    // move for routed samples.)
    for (i, b) in [&b1, &b2].iter().enumerate() {
        let served = b.coord.metrics().counter("applies_executed").get();
        assert!(served > 0, "backend {i} executed no applies (no cross-node routing)");
        println!("cluster-smoke: backend {i} executed {served} applies");
    }

    // Cluster stats: remote endpoints healthy, cache counters live.
    let (_, v) = rpc(&mut reader, &mut writer, r#"{"v": 2, "op": "stats"}"#);
    let stats = v.get_path("result.stats").expect("stats payload");
    let members = stats
        .get_path("cluster.sets.gp.members")
        .and_then(Value::as_array)
        .expect("cluster members");
    assert_eq!(members.len(), 3);
    assert_eq!(members[0].get("endpoint").and_then(Value::as_str), Some("local"));
    for (i, b) in [&b1, &b2].iter().enumerate() {
        let m = &members[i + 1];
        assert_eq!(m.get("endpoint").and_then(Value::as_str), Some(format!("tcp:{}", b.addr).as_str()));
        assert_eq!(m.get("state").and_then(Value::as_str), Some("healthy"), "member {} not healthy", i + 1);
    }
    let hits = stats.get_path("cluster.cache.hits").and_then(Value::as_f64).expect("cache hits");
    assert!(hits >= 1.0, "stats cache hits");
    println!(
        "cluster-smoke: OK — cache hits {hits}, members healthy, bytes identical across nodes"
    );

    // Graceful teardown, front door first.
    stop.store(true, Ordering::SeqCst);
    handle.join().expect("front thread").expect("front run");
    if let Ok(front) = Arc::try_unwrap(front) {
        front.shutdown();
    }
    for b in [b1, b2] {
        b.stop.store(true, Ordering::SeqCst);
        b.handle.join().expect("backend thread").expect("backend run");
        if let Ok(coord) = Arc::try_unwrap(b.coord) {
            coord.shutdown();
        }
    }
    std::fs::remove_file(&sock).ok();
    println!("cluster-smoke: drained cleanly");
}
