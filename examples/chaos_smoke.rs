//! Chaos-smoke: the CI leg for the cluster resilience layer
//! (`DESIGN.md` §12).
//!
//! Spawns TWO backend `icr serve`-equivalents on ephemeral tcp ports,
//! then one front-door coordinator whose `gp` replica set mixes a local
//! native member with both remote backends — and arms the front door's
//! deterministic fault injector so EVERY remote data call fails
//! (`remote:error=1,delay_ms=1`) while control traffic (probes,
//! identity) stays green. Drives v2 traffic over the front door's unix
//! socket and asserts:
//!
//! - zero client-visible failures: every reply under chaos is `ok` and
//!   byte-identical to the single-node engine for the same seed;
//! - the failover path actually ran (`failovers` >= 1) and every retry
//!   stayed inside its deadline budget (no `retry_budget_exhausted`);
//! - both remote members tripped their request-level circuit breakers
//!   (>= 1 trip each) while staying probe-healthy (no ejections);
//! - recovery: once the injector is disarmed mid-run, half-open trials
//!   on live traffic close both breakers again within the deadline.
//!
//! The final stats document is written to `ICR_CHAOS_DIR` (default
//! `chaos-smoke/`) as `stats.json` so CI can upload it. Exits non-zero
//! on any violation.
//!
//! ```text
//! cargo run --release --example chaos_smoke
//! ```

use std::io::{BufRead, BufReader, Write};
use std::os::unix::net::UnixStream;
use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use icr::config::{Backend, MemberSpec, ModelConfig, ReplicaSpec, ServerConfig};
use icr::coordinator::Coordinator;
use icr::json::Value;
use icr::model::GpModel;
use icr::net::{BreakerState, ListenAddr, NetServer};

fn small_model() -> ModelConfig {
    ModelConfig { n_csz: 3, n_fsz: 2, n_lvl: 3, target_n: 48, ..ModelConfig::default() }
}

struct Node {
    addr: String,
    #[allow(dead_code)]
    coord: Arc<Coordinator>,
    stop: Arc<std::sync::atomic::AtomicBool>,
    handle: std::thread::JoinHandle<anyhow::Result<()>>,
}

fn start_backend() -> Node {
    let cfg = ServerConfig {
        model: small_model(),
        workers: 2,
        max_batch: 8,
        max_wait_us: 500,
        idle_timeout_ms: 0,
        listen: ListenAddr::Tcp("127.0.0.1:0".into()),
        ..ServerConfig::default()
    };
    let coord = Arc::new(Coordinator::start(cfg.clone()).expect("backend coordinator"));
    let server = NetServer::bind(&cfg, coord.clone()).expect("bind backend");
    let addr = server.local_addr().strip_prefix("tcp:").expect("tcp addr").to_string();
    let stop = server.shutdown_handle();
    let handle = std::thread::spawn(move || server.run());
    Node { addr, coord, stop, handle }
}

fn rpc(reader: &mut BufReader<UnixStream>, writer: &mut UnixStream, line: &str) -> Value {
    writeln!(writer, "{line}").expect("send");
    writer.flush().expect("flush");
    let mut resp = String::new();
    let n = reader.read_line(&mut resp).expect("recv");
    assert!(n > 0, "server hung up mid-request");
    Value::parse(resp.trim()).unwrap_or_else(|e| panic!("bad frame {resp:?}: {e}"))
}

fn sample_row(v: &Value) -> Vec<f64> {
    assert_eq!(v.get("ok").and_then(Value::as_bool), Some(true), "client-visible failure: {v:?}");
    v.get_path("result.samples")
        .and_then(Value::as_array)
        .expect("samples")[0]
        .as_array()
        .expect("row")
        .iter()
        .filter_map(Value::as_f64)
        .collect()
}

fn main() {
    let b1 = start_backend();
    let b2 = start_backend();
    println!("chaos-smoke: shards on tcp:{} and tcp:{}", b1.addr, b2.addr);

    // Front door: local + both shards, chaos armed from boot. Control
    // traffic bypasses the injector, so both remote members come up
    // healthy and STAY probe-healthy while every request to them fails
    // — exactly the failure mode only request-level breakers catch.
    let members = vec![
        MemberSpec::local(Backend::Native),
        MemberSpec::remote(&format!("tcp:{}", b1.addr)).expect("member b1"),
        MemberSpec::remote(&format!("tcp:{}", b2.addr)).expect("member b2"),
    ];
    let sock = std::env::temp_dir().join(format!("icr_chaos_{}.sock", std::process::id()));
    let _ = std::fs::remove_file(&sock);
    let cfg = ServerConfig {
        model: small_model(),
        workers: 2,
        max_batch: 8,
        max_wait_us: 500,
        idle_timeout_ms: 0,
        health_interval_ms: 100,
        breaker_window: 4,
        breaker_trip_ratio: 0.5,
        breaker_cooldown_ms: 100,
        retry_max: 3,
        retry_budget_ms: 10_000,
        fault_inject: Some("remote:error=1,delay_ms=1".into()),
        replicas: vec![ReplicaSpec::new("gp", members).expect("replica spec")],
        listen: ListenAddr::Unix(sock.clone()),
        ..ServerConfig::default()
    };
    let front = Arc::new(Coordinator::start(cfg.clone()).expect("front door"));
    let server = NetServer::bind(&cfg, front.clone()).expect("bind front");
    let stop = server.shutdown_handle();
    let handle = std::thread::spawn(move || server.run());
    let engine = front.engine().clone();

    let s = UnixStream::connect(&sock).expect("connect front");
    s.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    let mut reader = BufReader::new(s.try_clone().expect("clone"));
    let mut writer = s;

    // Phase 1 — chaos on: every remote attempt fails, failover lands on
    // the local member, and the client never sees any of it.
    for seed in 0..48u64 {
        let frame = format!(
            r#"{{"v": 2, "op": "sample", "model": "gp", "id": {seed}, "count": 1, "seed": {seed}}}"#
        );
        let got = sample_row(&rpc(&mut reader, &mut writer, &frame));
        let want = engine.sample(1, seed).expect("engine sample").remove(0);
        assert_eq!(got, want, "seed {seed} diverged from single-node bytes under chaos");
    }
    let trips1 = front.router().breaker_trips("gp@1").expect("gp@1 breaker");
    let trips2 = front.router().breaker_trips("gp@2").expect("gp@2 breaker");
    let failovers = front.metrics().counter("failovers").get();
    println!(
        "chaos-smoke: under chaos — trips gp@1={trips1} gp@2={trips2} failovers={failovers}"
    );
    assert!(trips1 >= 1, "gp@1 never tripped under full-error chaos");
    assert!(trips2 >= 1, "gp@2 never tripped under full-error chaos");
    assert!(failovers >= 1, "no successful failover recorded");
    assert_eq!(
        front.metrics().counter("retry_budget_exhausted").get(),
        0,
        "a request ran out of retry budget — should never happen with a clean local member"
    );
    assert_eq!(
        front.metrics().counter("health_ejections").get(),
        0,
        "request chaos must stay invisible to health probes"
    );

    // Phase 2 — chaos off: half-open trials on live traffic succeed and
    // both breakers close again, still byte-identical throughout.
    front.fault_injector().expect("front injector").set_armed(false);
    let deadline = Instant::now() + Duration::from_secs(30);
    let mut seed = 1000u64;
    loop {
        let closed = |m: &str| front.router().breaker_state(m) == Some(BreakerState::Closed);
        if closed("gp@1") && closed("gp@2") {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "breakers never closed after chaos cleared: gp@1={:?} gp@2={:?}",
            front.router().breaker_state("gp@1"),
            front.router().breaker_state("gp@2"),
        );
        let frame = format!(
            r#"{{"v": 2, "op": "sample", "model": "gp", "id": {seed}, "count": 1, "seed": {seed}}}"#
        );
        let got = sample_row(&rpc(&mut reader, &mut writer, &frame));
        let want = engine.sample(1, seed).expect("engine sample").remove(0);
        assert_eq!(got, want, "seed {seed} diverged during recovery");
        seed += 1;
        std::thread::sleep(Duration::from_millis(10));
    }
    println!("chaos-smoke: both breakers closed after disarm ({} recovery probes)", seed - 1000);

    // Dump the stats document for the CI artifact.
    let stats = rpc(&mut reader, &mut writer, r#"{"v": 2, "op": "stats", "id": 1}"#);
    let doc = stats.get_path("result.stats").expect("stats document");
    let fault = doc.get_path("cluster.fault").expect("fault section");
    assert_eq!(fault.get("armed").and_then(Value::as_bool), Some(false), "{fault:?}");
    assert!(
        fault.get_path("injected.errors").and_then(Value::as_f64).unwrap_or(0.0) >= 1.0,
        "injector never fired: {fault:?}"
    );
    let dir =
        PathBuf::from(std::env::var("ICR_CHAOS_DIR").unwrap_or_else(|_| "chaos-smoke".into()));
    std::fs::create_dir_all(&dir).expect("create dump dir");
    let path = dir.join("stats.json");
    std::fs::write(&path, doc.to_json_pretty()).expect("write stats dump");
    println!("chaos-smoke: stats dumped to {}", path.display());

    drop(reader);
    drop(writer);
    stop.store(true, Ordering::SeqCst);
    handle.join().unwrap().unwrap();
    std::fs::remove_file(&sock).ok();
    b1.stop.store(true, Ordering::SeqCst);
    b2.stop.store(true, Ordering::SeqCst);
    let _ = b1.handle.join();
    let _ = b2.handle.join();
    println!("chaos-smoke: OK");
}
