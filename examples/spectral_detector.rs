//! Spectral pixel detector — the chart example from the paper's §4.3:
//! "a pixel detector measuring energies might have a regular, spatial
//! pixel axis and a logarithmic, spectral energy axis."
//!
//! We model the detector's expected log-count surface as a separable GP
//! on (pixel × log-energy), draw the true surface from the prior, observe
//! noisy counts, and reconstruct the energy spectrum per pixel with the
//! standardized-VI machinery (paper Eq. 3) along the energy axis.
//!
//! Run: `cargo run --release --example spectral_detector`

use icr::chart::{IdentityChart, LogChart};
use icr::icr::{Geometry, IcrEngine, RefinementParams};
use icr::kernels::Matern;
use icr::optim::Adam;
use icr::rng::Rng;

fn main() -> anyhow::Result<()> {
    // Spatial axis: 96 pixels, regular, stationary broadcast path.
    let px_params = RefinementParams::for_target(3, 2, 4, 96)?;
    let px_kernel = Matern::nu32(6.0, 1.0); // features span ~6 pixels
    let px = IcrEngine::build(&px_kernel, &IdentityChart::unit(), px_params)?;

    // Energy axis: 1–100 keV on a log chart (constant resolution ΔE/E).
    let en_params = RefinementParams::for_target(5, 4, 4, 128)?;
    let egeo = Geometry::build(en_params);
    let efin = egeo.final_positions();
    let beta = (100.0_f64 / 1.0).ln() / (efin[efin.len() - 1] - efin[0]);
    let alpha = 1.0_f64.ln() - beta * efin[0];
    let en_chart = LogChart::new(alpha, beta);
    // Detector response correlated over ~8 keV *in energy*: on the log
    // grid that spans bins that are densely packed (ΔE ≪ ρ at 1 keV) to
    // sparsely packed (ΔE ≈ ρ/2 at 100 keV) — spacing variation over two
    // orders of magnitude, exactly the regime the chart exists for (§5).
    let en_kernel = Matern::nu32(8.0, 0.8);
    let en = IcrEngine::build(&en_kernel, &en_chart, en_params)?;

    let (np_, ne) = (px.n_points(), en.n_points());
    println!(
        "detector: {np_} pixels × {ne} energy bins ({:.2}–{:.0} keV, log axis)",
        en.domain_points()[0],
        en.domain_points()[ne - 1]
    );

    // --- Ground truth: one draw of the separable prior. -----------------
    let mut rng = Rng::new(0xDE7EC70);
    let xi: Vec<f64> = rng.standard_normal_vec(px.total_dof() * en.total_dof());
    // s = √K_px · Ξ · √K_enᵀ  (apply energy axis per row, then pixel axis
    // per column).
    let mut half = vec![0.0; px.total_dof() * ne];
    for i in 0..px.total_dof() {
        let s = en.apply_sqrt(&xi[i * en.total_dof()..(i + 1) * en.total_dof()]);
        half[i * ne..(i + 1) * ne].copy_from_slice(&s);
    }
    let mut truth = vec![0.0; np_ * ne];
    let mut col = vec![0.0; px.total_dof()];
    for j in 0..ne {
        for i in 0..px.total_dof() {
            col[i] = half[i * ne + j];
        }
        let s = px.apply_sqrt(&col);
        for i in 0..np_ {
            truth[i * ne + j] = s[i];
        }
    }

    // --- Observation: noisy log-counts on every second energy bin. ------
    let sigma_n = 0.1;
    let obs_idx: Vec<usize> = (0..ne).step_by(2).collect();

    // --- Per-pixel spectral inference along the energy axis (Eq. 3). ----
    // Each pixel row is an independent 1-D GP regression with the energy
    // engine as prior: minimize ½‖(y−A√K ξ)/σ‖² + ½‖ξ‖² with Adam using
    // the engine's hand-derived adjoint.
    let report_pixels = [np_ / 4, np_ / 2, 3 * np_ / 4];
    let mut total_rmse = 0.0;
    let t0 = std::time::Instant::now();
    for pix in 0..np_ {
        let row = &truth[pix * ne..(pix + 1) * ne];
        let y: Vec<f64> =
            obs_idx.iter().map(|&j| row[j] + sigma_n * rng.standard_normal()).collect();

        let dof = en.total_dof();
        let mut xi_fit = vec![0.0; dof];
        let mut opt = Adam::new(dof, 0.2);
        let inv_var = 1.0 / (sigma_n * sigma_n);
        let mut last_loss = 0.0;
        for _ in 0..400 {
            let s = en.apply_sqrt(&xi_fit);
            let mut cot = vec![0.0; ne];
            let mut loss = 0.0;
            for (&j, &yj) in obs_idx.iter().zip(&y) {
                let r = s[j] - yj;
                loss += 0.5 * r * r * inv_var;
                cot[j] = r * inv_var;
            }
            loss += 0.5 * xi_fit.iter().map(|v| v * v).sum::<f64>();
            let mut grad = en.apply_sqrt_transpose(&cot);
            for (g, &x) in grad.iter_mut().zip(&xi_fit) {
                *g += x;
            }
            opt.step(&mut xi_fit, &grad);
            last_loss = loss;
        }
        let recon = en.apply_sqrt(&xi_fit);
        let rmse = (recon
            .iter()
            .zip(row)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            / ne as f64)
            .sqrt();
        total_rmse += rmse;
        if report_pixels.contains(&pix) {
            println!(
                "pixel {pix:3}: final loss {last_loss:9.2}, spectrum RMSE {rmse:.3} \
                 (noise σ = {sigma_n})"
            );
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    let mean_rmse = total_rmse / np_ as f64;
    println!(
        "\nreconstructed {np_} spectra ({} obs each) in {dt:.2}s — mean RMSE {mean_rmse:.3}",
        obs_idx.len()
    );
    // The reconstruction must beat the noise-free prior scale (≈0.8) and
    // approach the noise floor.
    anyhow::ensure!(mean_rmse < 0.2, "spectral reconstruction too poor: {mean_rmse}");
    println!("OK: mean RMSE {mean_rmse:.3} ≪ prior std 0.8 — energy-axis chart works");
    Ok(())
}
