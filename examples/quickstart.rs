//! Quickstart: sample a Gaussian process with ICR in O(N).
//!
//! Builds the paper's §5 model — a Matérn-3/2 GP on ~200 logarithmically
//! spaced points whose nearest-neighbour distances sweep two orders of
//! magnitude — draws samples through the coordinator, and verifies the
//! key §5.2 property live: the implicit covariance is full rank.
//!
//! Run: `cargo run --release --example quickstart`

use icr::config::ServerConfig;
use icr::coordinator::{Coordinator, Request, Response};
use icr::gp::{covariance_errors, kernel_matrix, rank_probe};
use icr::kernels::Matern;

fn main() -> anyhow::Result<()> {
    // 1. The paper-default configuration: Matérn-3/2 (Eq. 14), log chart,
    //    (n_csz, n_fsz) = (5, 4), n_lvl = 5, N = 200.
    let cfg = ServerConfig::default();
    println!("model: {}", cfg.model.to_json().to_json());

    // 2. Start the coordinator (native Rust engine, no artifacts needed).
    let coord = Coordinator::start(cfg)?;
    let engine = coord.engine();
    println!(
        "engine: {} | N = {} modeled points, {} excitation dof",
        engine.name(),
        engine.n_points(),
        engine.total_dof()
    );
    let pts = engine.domain_points();
    println!(
        "modeled points span [{:.3}, {:.3}]·ρ₀, nn-spacing {:.3}…{:.3}",
        pts[0],
        pts[pts.len() - 1],
        pts[1] - pts[0],
        pts[pts.len() - 1] - pts[pts.len() - 2]
    );

    // 3. Draw three samples (one batched request; the batcher coalesces).
    let resp = coord.call(Request::Sample { count: 3, seed: 42 })?;
    let samples = match resp {
        Response::Samples(s) => s,
        other => anyhow::bail!("unexpected response {other:?}"),
    };
    for (i, s) in samples.iter().enumerate() {
        let mean = s.iter().sum::<f64>() / s.len() as f64;
        let std = (s.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / s.len() as f64).sqrt();
        println!("sample {i}: mean {mean:+.3}, std {std:.3}, head {:?}", &s[..4]);
    }

    // 4. The paper's key structural claims, verified on the spot.
    let native = icr::coordinator::NativeEngine::from_config(&ServerConfig::default().model)?;
    let k_icr = native.inner().implicit_covariance();
    let probe = rank_probe(&k_icr);
    println!(
        "\nK_ICR rank: {}/{} (λ_min = {:.2e}) — full rank by construction (§5.2)",
        probe.rank,
        native.inner().n_points(),
        probe.lambda_min
    );
    let kernel = Matern::nu32(1.0, 1.0);
    let truth = kernel_matrix(&kernel, native.inner().domain_points());
    let errs = covariance_errors(&k_icr, &truth);
    println!(
        "covariance accuracy vs exact kernel: MAE {:.2e}, max {:.2e} (paper: 5.8e-3, 1.3e-1)",
        errs.mae, errs.max_abs
    );

    coord.shutdown();
    Ok(())
}
