//! Quickstart: the unified `GpModel` API in five steps.
//!
//! 1. Build the paper's §5 model with the fluent `ModelBuilder`.
//! 2. Sample it directly — `√K_ICR · ξ` in O(N).
//! 3. Stand up a multi-model coordinator (native ICR + the KISS-GP
//!    baseline) and route requests by model id, exactly like
//!    `icr serve --models kiss=kissgp` does over JSONL protocol v2.
//! 4. Run posterior inference through the same interface.
//! 5. Verify the key §5.2 structural claim live: `K_ICR` is full rank.
//!
//! Run: `cargo run --release --example quickstart`
//!
//! The JSONL equivalent of step 3 (two models in one `icr serve`
//! process):
//!
//! ```text
//! $ icr serve --models kiss=kissgp <<'EOF'
//! {"op": "sample", "count": 1, "seed": 7}
//! {"v": 2, "op": "sample", "model": "kiss", "id": 1, "count": 1, "seed": 7}
//! {"v": 2, "op": "stats", "id": 2}
//! EOF
//! ```
//!
//! The first (bare v1) line is answered by the default native model; the
//! tagged v2 lines route by `model` and echo the client `id`.

use icr::prelude::*;

fn main() -> anyhow::Result<()> {
    // 1. The paper-default model through the builder: Matérn-3/2 (Eq. 14),
    //    log chart, (n_csz, n_fsz) = (5, 4), n_lvl = 5, N = 200.
    let model = <dyn GpModel>::builder()
        .kernel("matern32(rho=1.0, amp=1.0)")
        .chart("paper_log")
        .windows(5, 4)
        .levels(5)
        .target_n(200)
        .backend(Backend::Native)
        .build()?;
    let d = model.descriptor();
    println!(
        "model: {} | backend {} | kernel {} | chart {} | N = {}, dof = {}",
        d.name, d.backend, d.kernel, d.chart, d.n, d.dof
    );
    let pts = model.domain_points();
    println!(
        "modeled points span [{:.3}, {:.3}]·ρ₀, nn-spacing {:.3}…{:.3}",
        pts[0],
        pts[pts.len() - 1],
        pts[1] - pts[0],
        pts[pts.len() - 1] - pts[pts.len() - 2]
    );

    // 2. Three seeded samples straight from the model (no server needed).
    for (i, s) in model.sample(3, 42)?.iter().enumerate() {
        let mean = s.iter().sum::<f64>() / s.len() as f64;
        let std = (s.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / s.len() as f64).sqrt();
        println!("sample {i}: mean {mean:+.3}, std {std:.3}, head {:?}", &s[..4]);
    }

    // 3. A two-model coordinator: the default native model plus the
    //    KISS-GP baseline on the SAME modeled points, routed by name.
    let mut cfg = ServerConfig::default();
    cfg.extra_models = vec![ModelSpec::local("kiss", Backend::Kissgp, cfg.model.clone())];
    let coord = Coordinator::start(cfg)?;
    println!("\ncoordinator hosts: {:?}", coord.model_names());
    for name in ["default", "kiss"] {
        match coord.call_model(Some(name), Request::Sample { count: 1, seed: 7 })? {
            Response::Samples(s) => println!(
                "  {name:>7} → sample of {} points (head {:+.3}, {:+.3})",
                s[0].len(),
                s[0][0],
                s[0][1]
            ),
            other => anyhow::bail!("unexpected response {other:?}"),
        }
    }

    // 4. Posterior inference (MAP of the standardized objective, Eq. 3)
    //    on data drawn from the native model itself.
    let truth = model.sample(1, 2027)?.remove(0);
    let sigma = 0.05;
    let mut rng = Rng::new(11);
    let y: Vec<f64> =
        model.obs_indices().iter().map(|&i| truth[i] + sigma * rng.standard_normal()).collect();
    match coord.call(Request::Infer { y_obs: y, sigma_n: sigma, steps: 200, lr: 0.1 })? {
        Response::Inference { field, trace } => {
            let rmse = (field
                .iter()
                .zip(&truth)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()
                / field.len() as f64)
                .sqrt();
            println!(
                "\ninference: loss {:.3e} → {:.3e} in {} steps, reconstruction RMSE {rmse:.4}",
                trace.losses[0],
                trace.losses[trace.losses.len() - 1],
                trace.losses.len()
            );
        }
        other => anyhow::bail!("unexpected response {other:?}"),
    }

    // 5. The paper's key structural claims, verified on the spot.
    let native = NativeEngine::from_config(&ServerConfig::default().model)?;
    let k_icr = native.inner().implicit_covariance();
    let probe = icr::gp::rank_probe(&k_icr);
    println!(
        "\nK_ICR rank: {}/{} (λ_min = {:.2e}) — full rank by construction (§5.2)",
        probe.rank,
        native.inner().n_points(),
        probe.lambda_min
    );
    let kernel = Matern::nu32(1.0, 1.0);
    let truth_k = icr::gp::kernel_matrix(&kernel, native.inner().domain_points());
    let errs = icr::gp::covariance_errors(&k_icr, &truth_k);
    println!(
        "covariance accuracy vs exact kernel: MAE {:.2e}, max {:.2e} (paper: 5.8e-3, 1.3e-1)",
        errs.mae, errs.max_abs
    );

    coord.shutdown();
    Ok(())
}
