//! Serve-smoke: the CI leg for the network serving subsystem.
//!
//! Starts a Unix-socket server with a 2-member replica set, drives 50
//! mixed v1/v2 requests from 4 concurrent clients, checks the `stats`
//! transport gauges, then drains gracefully. Exits non-zero on any
//! failed frame or missing gauge.
//!
//! ```text
//! cargo run --release --example serve_smoke
//! ```

use std::io::{BufRead, BufReader, Write};
use std::os::unix::net::UnixStream;
use std::sync::atomic::Ordering;
use std::sync::Arc;

use icr::config::{Backend, ModelConfig, ReplicaSpec, ServerConfig};
use icr::coordinator::Coordinator;
use icr::json::Value;
use icr::net::{ListenAddr, NetServer, RoutePolicy};

fn rpc(reader: &mut BufReader<UnixStream>, writer: &mut UnixStream, line: &str) -> Value {
    writeln!(writer, "{line}").expect("send");
    writer.flush().expect("flush");
    let mut resp = String::new();
    let n = reader.read_line(&mut resp).expect("recv");
    assert!(n > 0, "server hung up mid-request");
    Value::parse(&resp).unwrap_or_else(|e| panic!("bad frame {resp:?}: {e}"))
}

fn main() {
    let sock = std::env::temp_dir().join(format!("icr_smoke_{}.sock", std::process::id()));
    let cfg = ServerConfig {
        model: ModelConfig { n_csz: 3, n_fsz: 2, n_lvl: 3, target_n: 48, ..ModelConfig::default() },
        workers: 2,
        max_batch: 8,
        max_wait_us: 1000,
        idle_timeout_ms: 0,
        listen: ListenAddr::Unix(sock.clone()),
        replicas: vec![ReplicaSpec::homogeneous("gp", Backend::Native, 2).unwrap()],
        route_policy: RoutePolicy::SeedAffinity,
        ..ServerConfig::default()
    };
    let coord = Arc::new(Coordinator::start(cfg.clone()).expect("coordinator"));
    let server = NetServer::bind(&cfg, coord.clone()).expect("bind");
    println!("serve-smoke: listening on {}", server.local_addr());
    let stop = server.shutdown_handle();
    let handle = std::thread::spawn(move || server.run());

    let n_obs = coord.engine().obs_indices().len();
    let y_json = vec!["0.2"; n_obs].join(",");

    // 4 concurrent clients × 12–13 mixed v1/v2 requests = 50 total.
    let per_client = [13usize, 13, 12, 12];
    std::thread::scope(|sc| {
        for (t, &count) in per_client.iter().enumerate() {
            let sock = sock.clone();
            let y_json = y_json.clone();
            sc.spawn(move || {
                let stream = UnixStream::connect(&sock).expect("connect");
                let mut reader = BufReader::new(stream.try_clone().expect("clone"));
                let mut writer = stream;
                for i in 0..count {
                    let seed = (t * 100 + i) as u64;
                    let v = match i % 4 {
                        0 => rpc(
                            &mut reader,
                            &mut writer,
                            &format!(r#"{{"op": "sample", "count": 1, "seed": {seed}}}"#),
                        ),
                        1 => rpc(
                            &mut reader,
                            &mut writer,
                            &format!(
                                r#"{{"v": 2, "op": "sample", "model": "gp", "id": {i}, "count": 2, "seed": {seed}}}"#
                            ),
                        ),
                        2 => rpc(
                            &mut reader,
                            &mut writer,
                            &format!(
                                r#"{{"v": 2, "op": "infer_multi", "id": {i}, "y_obs": [{y_json}], "sigma": 0.5, "steps": 5, "lr": 0.1, "restarts": 2, "seed": {seed}}}"#
                            ),
                        ),
                        _ => rpc(&mut reader, &mut writer, r#"{"v": 2, "op": "stats"}"#),
                    };
                    let failed = v.get("error").is_some()
                        || v.get("ok").and_then(Value::as_bool) == Some(false);
                    assert!(!failed, "client {t} request {i} failed: {}", v.to_json());
                }
            });
        }
    });

    // A final connection reads the stats document and checks the
    // transport gauges the dashboard scrapes.
    let stream = UnixStream::connect(&sock).expect("connect");
    let mut reader = BufReader::new(stream.try_clone().expect("clone"));
    let mut writer = stream;
    let v = rpc(&mut reader, &mut writer, r#"{"v": 2, "op": "stats"}"#);
    let stats = v.get_path("result.stats").expect("stats payload");
    let gauge = |path: &str| {
        stats
            .get_path(path)
            .and_then(Value::as_f64)
            .unwrap_or_else(|| panic!("missing stats path {path}"))
    };
    assert!(gauge("transport.counters.connections_total") >= 5.0, "connections_total");
    assert!(gauge("transport.counters.frames_in") >= 51.0, "frames_in");
    assert!(gauge("transport.counters.frames_out") >= 50.0, "frames_out");
    assert!(gauge("transport.gauges.connections_open") >= 1.0, "connections_open");
    assert!(gauge("transport.gauges.queue_depth") >= 0.0, "queue_depth");
    assert_eq!(
        stats.get_path("replica_sets.policy").and_then(Value::as_str),
        Some("seed_affinity")
    );
    let members = stats
        .get_path("replica_sets.sets.gp.members")
        .and_then(Value::as_array)
        .expect("replica members");
    assert_eq!(members.len(), 2);
    let routed: f64 =
        members.iter().filter_map(|m| m.get("routed").and_then(Value::as_f64)).sum();
    assert!(routed >= 1.0, "no request was routed through the replica set");
    drop(writer);
    drop(reader);

    // Graceful drain, then done.
    stop.store(true, Ordering::SeqCst);
    handle.join().expect("server thread").expect("server run");
    std::fs::remove_file(&sock).ok();
    println!(
        "serve-smoke OK: 50 mixed v1/v2 requests over 4 concurrent clients, {} applies in {} batches (mean batch {:.2})",
        coord.metrics().counter("applies_executed").get(),
        coord.metrics().histogram("batch_applies").count(),
        coord.metrics().counter("applies_executed").get() as f64
            / coord.metrics().histogram("batch_applies").count().max(1) as f64
    );
}
