//! Artifact-smoke: the CI leg for the persistence layer (`DESIGN.md`
//! §10).
//!
//! Exercises the full artifact lifecycle in one process:
//!
//! - save: a served coordinator optimizes a short MAP posterior,
//!   installs it, and writes a versioned artifact directory;
//! - load: the artifact is re-verified (payload sha256s + config
//!   checksum), the model rebuilt, and its samples byte-checked against
//!   the saver;
//! - warm start: a second coordinator restored from the artifact serves
//!   `infer` byte-identically to the saver's warm path;
//! - hot reload: a live coordinator swaps its default entry from a
//!   second artifact with a different geometry via the `reload_model`
//!   op and serves the new model's bytes;
//! - corruption: a byte-flipped payload is rejected with the typed
//!   checksum error and the old model keeps serving.
//!
//! The artifact directory is left on disk (`ICR_SMOKE_DIR`, default
//! `artifact-smoke/`) so CI can upload it. Exits non-zero on any
//! violation.
//!
//! ```text
//! cargo run --release --example artifact_smoke
//! ```

use std::path::PathBuf;

use icr::artifact::{self, config_checksum, Snapshot};
use icr::config::{Backend, ModelConfig, ServerConfig};
use icr::coordinator::{Coordinator, Request, Response};
use icr::error::IcrError;
use icr::model::ModelBuilder;
use icr::rng::Rng;

fn small_cfg() -> ServerConfig {
    ServerConfig {
        model: ModelConfig { n_csz: 3, n_fsz: 2, n_lvl: 3, target_n: 48, ..ModelConfig::default() },
        workers: 2,
        max_batch: 8,
        max_wait_us: 500,
        ..ServerConfig::default()
    }
}

fn main() {
    let dir = PathBuf::from(
        std::env::var("ICR_SMOKE_DIR").unwrap_or_else(|_| "artifact-smoke".into()),
    );
    let _ = std::fs::remove_dir_all(&dir);

    // --- Save: short MAP run, posterior installed, artifact written. ---
    let saver = Coordinator::start(small_cfg()).expect("saver coordinator");
    let engine = saver.engine();
    let dof = engine.total_dof();
    let mut rng = Rng::new(314);
    let y: Vec<f64> = rng.standard_normal_vec(engine.obs_indices().len());
    let (mi, xi) =
        engine.infer_multi_from(None, &y, 0.3, 60, 0.1, 2, 9).expect("MAP run");
    saver
        .install_posterior(None, xi[mi.best * dof..(mi.best + 1) * dof].to_vec())
        .expect("install posterior");
    let snap = saver.save_artifact(None, &dir).expect("save artifact");
    println!(
        "artifact-smoke: saved {:?} (N = {}, dof = {}, config sha256 {}) -> {}",
        snap.name,
        snap.descriptor.n,
        snap.descriptor.dof,
        snap.config_sha256(),
        dir.display()
    );

    // --- Load: verified rebuild, byte-identical samples. ---
    let (loaded, back) = artifact::load_model(&dir, None, "artifacts").expect("load artifact");
    assert_eq!(back.config_sha256(), snap.config_sha256());
    assert_eq!(
        loaded.sample(3, 2718).expect("loaded sample"),
        engine.sample(3, 2718).expect("saver sample"),
        "loaded model's samples diverged from the saver"
    );
    println!("artifact-smoke: load OK — samples byte-identical to the saver");

    // --- Warm start: restored server answers infer like the saver. ---
    let warm_saver = match saver
        .call(Request::Infer { y_obs: y.clone(), sigma_n: 0.3, steps: 10, lr: 0.1 })
        .expect("saver warm infer")
    {
        Response::Inference { field, .. } => field,
        other => panic!("{other:?}"),
    };
    let mut cfg = small_cfg();
    cfg.model = back.config.clone();
    cfg.backend = back.backend;
    let loader = Coordinator::start(cfg).expect("loader coordinator");
    back.verify_model(loader.engine().as_ref()).expect("geometry parity");
    loader
        .install_posterior(None, back.posterior.clone().expect("posterior payload"))
        .expect("install restored posterior");
    let warm_loader = match loader
        .call(Request::Infer { y_obs: y, sigma_n: 0.3, steps: 10, lr: 0.1 })
        .expect("loader warm infer")
    {
        Response::Inference { field, .. } => field,
        other => panic!("{other:?}"),
    };
    assert_eq!(warm_saver, warm_loader, "warm inference diverged across save/load");
    println!("artifact-smoke: warm start OK — restored infer byte-identical");
    saver.shutdown();

    // --- Hot reload: swap the loader's entry to a bigger geometry. ---
    let deploy_dir = dir.join("next");
    let next = ModelBuilder::new().windows(3, 2).levels(3).target_n(64);
    let next_cfg = next.config().clone();
    let next_model = next.build().expect("next model");
    let next_snap =
        Snapshot::capture("default", Backend::Native, &next_cfg, next_model.as_ref(), None, 0)
            .expect("next snapshot");
    artifact::save(&deploy_dir, &next_snap).expect("save next artifact");
    match loader
        .call(Request::ReloadModel { path: deploy_dir.to_string_lossy().into_owned() })
        .expect("reload op")
    {
        Response::Reloaded { model, config_sha256 } => {
            assert_eq!(model, "default");
            assert_eq!(config_sha256, config_checksum(&next_cfg));
        }
        other => panic!("{other:?}"),
    }
    assert_eq!(loader.engine().n_points(), 64, "reload did not swap the entry");
    assert_eq!(
        loader.engine().sample(1, 11).expect("reloaded sample"),
        next_model.sample(1, 11).expect("next sample"),
        "reloaded entry serves wrong bytes"
    );
    println!("artifact-smoke: hot reload OK — entry swapped to the new geometry");

    // --- Corruption: byte-flip rejected, old model keeps serving. ---
    let evil_dir = dir.join("corrupt");
    artifact::save(&evil_dir, &next_snap).expect("save corruptible artifact");
    let payload = evil_dir.join("domain.bin");
    let mut bytes = std::fs::read(&payload).expect("read payload");
    bytes[7] ^= 0x20;
    std::fs::write(&payload, &bytes).expect("tamper payload");
    match loader.reload_model_from(None, &evil_dir) {
        Err(IcrError::ChecksumMismatch { what, .. }) => {
            assert!(what.contains("domain.bin"), "wrong subject: {what}");
        }
        other => panic!("corrupt artifact accepted: {other:?}"),
    }
    assert_eq!(loader.engine().n_points(), 64, "failed reload must not swap");
    let _ = std::fs::remove_dir_all(&evil_dir);
    println!("artifact-smoke: corruption rejected with typed checksum error");

    loader.shutdown();
    println!("artifact-smoke: OK");
}
