//! END-TO-END driver: the full three-layer stack on a real workload.
//!
//! Pipeline (nothing mocked):
//!   1. Python built the artifacts once (`make artifacts`): the Pallas
//!      refinement kernels (L1), chained into the JAX model (L2), lowered
//!      with `jax.value_and_grad` to the `icr_loss_grad_c5f4_n200` HLO.
//!   2. This binary (L3) loads that executable via PJRT, generates a
//!      synthetic dataset on the paper's §5 geometry (N = 200 log-spaced
//!      points, Matérn-3/2, noise σ), and runs a few hundred Adam steps
//!      of standardized VI (paper Eq. 3) — every step is exactly two
//!      applications of √K_ICR (forward + adjoint), as §1 promises.
//!   3. It logs the loss curve, reports reconstruction RMSE on held-out
//!      points, cross-checks the PJRT lane against the native engine, and
//!      writes `results/e2e_loss_curve.csv` (recorded in EXPERIMENTS.md).
//!
//! Run: `make artifacts && cargo run --release --example regression_e2e`
//! (falls back to the native engine if artifacts are missing).

use std::path::Path;

use icr::config::{Backend, ModelConfig, ServerConfig};
use icr::coordinator::{Coordinator, FieldEngine, NativeEngine, Request, Response};
use icr::rng::Rng;

fn main() -> anyhow::Result<()> {
    let have_artifacts = Path::new("artifacts/manifest.json").exists();
    let backend = if have_artifacts { Backend::Pjrt } else { Backend::Native };
    if !have_artifacts {
        eprintln!("WARNING: artifacts/ missing — falling back to the native engine");
    }

    let cfg = ServerConfig { backend, workers: 2, ..ServerConfig::default() };
    let coord = Coordinator::start(cfg)?;
    println!("engine: {}", coord.engine().name());

    // --- Synthetic dataset from the model's own prior. ------------------
    // (The native engine provides the ground truth so we can score the
    // reconstruction; it matches the artifact's geometry bit-for-bit —
    // asserted by tests/artifact_integration.rs.)
    let native = NativeEngine::from_config(&ModelConfig::default())?;
    let sigma_n = 0.05;
    let mut rng = Rng::new(0xE2E);
    let xi_true = rng.standard_normal_vec(native.total_dof());
    let truth = native.apply_sqrt_batch(std::slice::from_ref(&xi_true))?.remove(0);
    let obs = native.obs_indices();
    let y_obs: Vec<f64> =
        obs.iter().map(|&i| truth[i] + sigma_n * rng.standard_normal()).collect();
    println!(
        "dataset: {} noisy observations (σ = {sigma_n}) of a {}-point GP draw; {} held out",
        obs.len(),
        truth.len(),
        truth.len() - obs.len()
    );

    // --- Optimize the standardized posterior (Eq. 3). -------------------
    let steps = 400;
    let t0 = std::time::Instant::now();
    let resp = coord.call(Request::Infer {
        y_obs: y_obs.clone(),
        sigma_n,
        steps,
        lr: 0.1,
    })?;
    let (field, trace) = match resp {
        Response::Inference { field, trace } => (field, trace),
        other => anyhow::bail!("unexpected response {other:?}"),
    };
    let wall = t0.elapsed().as_secs_f64();

    // --- Score. ----------------------------------------------------------
    let rmse_all = rmse(&field, &truth);
    let held_out: Vec<usize> = (1..truth.len()).step_by(2).collect();
    let rmse_held: f64 = {
        let se: f64 = held_out.iter().map(|&i| (field[i] - truth[i]).powi(2)).sum();
        (se / held_out.len() as f64).sqrt()
    };
    let scale =
        (truth.iter().map(|v| v * v).sum::<f64>() / truth.len() as f64).sqrt();

    println!("\nloss curve (step:loss): {}", trace.summary(steps / 10));
    println!(
        "loss {:.3e} → {:.3e} in {steps} steps ({wall:.2}s wall, {:.1} ms/step)",
        trace.losses[0],
        trace.losses[steps - 1],
        1e3 * wall / steps as f64
    );
    println!("reconstruction RMSE: all points {rmse_all:.4}, held-out {rmse_held:.4} (field scale {scale:.3}, noise {sigma_n})");

    // --- Cross-check the lanes (when both available). -------------------
    if have_artifacts {
        let (l_pjrt, g_pjrt) =
            coord.engine().loss_grad(&vec![0.0; native.total_dof()], &y_obs, sigma_n)?;
        let (l_nat, g_nat) =
            native.loss_grad(&vec![0.0; native.total_dof()], &y_obs, sigma_n)?;
        let gdiff = g_pjrt
            .iter()
            .zip(&g_nat)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0_f64, f64::max);
        println!(
            "lane agreement at ξ=0: |Δloss| = {:.2e}, max|Δgrad| = {gdiff:.2e}",
            (l_pjrt - l_nat).abs()
        );
        // Tolerance: the two lanes sum ~1e4-scale likelihood terms in
        // different orders; 1e-7 absolute on an O(100) gradient is ~1 ulp
        // per accumulation step.
        anyhow::ensure!(gdiff < 1e-7, "PJRT and native gradients diverge: {gdiff}");
    }

    // --- Persist the loss curve. ----------------------------------------
    std::fs::create_dir_all("results")?;
    let mut csv = String::from("step,loss\n");
    for (i, l) in trace.losses.iter().enumerate() {
        csv.push_str(&format!("{i},{l}\n"));
    }
    std::fs::write("results/e2e_loss_curve.csv", csv)?;
    println!("→ results/e2e_loss_curve.csv");

    // Hard success criteria (this example doubles as an acceptance test).
    anyhow::ensure!(
        trace.losses[steps - 1] < 0.02 * trace.losses[0],
        "loss did not drop by 50×: {} → {}",
        trace.losses[0],
        trace.losses[steps - 1]
    );
    anyhow::ensure!(
        rmse_held < 0.5 * scale,
        "held-out RMSE {rmse_held} not better than half the field scale {scale}"
    );
    println!("\nE2E OK: three-layer stack (Pallas → JAX → HLO → PJRT → Rust Adam) converged.");
    coord.shutdown();
    Ok(())
}

fn rmse(a: &[f64], b: &[f64]) -> f64 {
    let se: f64 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
    (se / a.len() as f64).sqrt()
}
