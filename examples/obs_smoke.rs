//! Obs-smoke: the CI leg for the observability layer (`DESIGN.md` §13).
//!
//! Spawns one backend `icr serve`-equivalent on an ephemeral tcp port
//! (with a fixed 10 ms injected model-call delay so the remote wire
//! span has a measurable floor), then a front-door coordinator whose
//! `gp` replica set mixes a local native member with that backend —
//! tracing sampled at 100% and a real `--metrics-listen` endpoint on an
//! ephemeral port. Drives v2 traffic over the front door's unix socket
//! and asserts:
//!
//! - byte parity: untraced replies carry no `trace` field and match the
//!   single-node engine byte-for-byte;
//! - `"trace": true` echoes a span tree whose `remote_wire` span covers
//!   at least the injected backend delay and nests the backend's joined
//!   `remote:request` span;
//! - the v2 `traces` op returns committed span trees from the ring;
//! - a real HTTP scrape of the metrics endpoint answers 200 with
//!   Prometheus text format 0.0.4 (`icr_` families, `_total` counters,
//!   `icr_build_info`, cumulative histogram buckets);
//! - profiling (`DESIGN.md` §14): after a burst of pooled panel-apply
//!   load under a running phase profiler, the `profile` op dumps a
//!   folded collapsed-stack document containing `request;panel_apply`,
//!   and a second scrape shows nonzero worker-pool busy-seconds plus
//!   the `icr_pool_saturation` gauge.
//!
//! The scrape body, the echoed span tree and the folded profile are
//! written to `ICR_OBS_DIR` (default `obs-smoke/`) as `metrics.txt`,
//! `trace.json` and `profile.folded` so CI can upload them. Exits
//! non-zero on any violation.
//!
//! ```text
//! cargo run --release --example obs_smoke
//! ```

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::os::unix::net::UnixStream;
use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use icr::config::{Backend, MemberSpec, ModelConfig, ReplicaSpec, ServerConfig};
use icr::coordinator::Coordinator;
use icr::json::Value;
use icr::net::{ListenAddr, NetServer};

/// Shared by the backend and the front door (replica-set members must
/// serve identical bytes). Sized so the deepest refinement levels clear
/// the pool's inline-fallback gate: with `count: 8` applies the worker
/// pool actually engages, giving the §14 profiling leg real
/// busy-seconds to reconcile against.
fn smoke_model() -> ModelConfig {
    ModelConfig { n_csz: 3, n_fsz: 2, n_lvl: 10, target_n: 16_384, ..ModelConfig::default() }
}

struct Node {
    addr: String,
    stop: Arc<std::sync::atomic::AtomicBool>,
    handle: std::thread::JoinHandle<anyhow::Result<()>>,
}

fn start_backend() -> Node {
    let cfg = ServerConfig {
        model: smoke_model(),
        workers: 2,
        max_batch: 8,
        max_wait_us: 500,
        idle_timeout_ms: 0,
        listen: ListenAddr::Tcp("127.0.0.1:0".into()),
        // Fixed delay on every model call: the floor under the front
        // door's remote_wire span duration.
        fault_inject: Some("local:delay_ms=10".into()),
        ..ServerConfig::default()
    };
    let coord = Arc::new(Coordinator::start(cfg.clone()).expect("backend coordinator"));
    let server = NetServer::bind(&cfg, coord).expect("bind backend");
    let addr = server.local_addr().strip_prefix("tcp:").expect("tcp addr").to_string();
    let stop = server.shutdown_handle();
    let handle = std::thread::spawn(move || server.run());
    Node { addr, stop, handle }
}

struct Client {
    reader: BufReader<UnixStream>,
    writer: UnixStream,
}

impl Client {
    fn connect(path: &std::path::Path) -> Client {
        let s = UnixStream::connect(path).expect("connect front door");
        s.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
        let r = s.try_clone().expect("clone");
        Client { reader: BufReader::new(r), writer: s }
    }

    fn send(&mut self, line: &str) {
        writeln!(self.writer, "{line}").expect("send");
        self.writer.flush().expect("flush");
    }

    fn recv_line(&mut self) -> String {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line).expect("recv");
        assert!(n > 0, "unexpected EOF from front door");
        line.truncate(line.trim_end().len());
        line
    }

    fn rpc(&mut self, line: &str) -> Value {
        self.send(line);
        let reply = self.recv_line();
        Value::parse(&reply).unwrap_or_else(|e| panic!("bad frame {reply:?}: {e}"))
    }
}

/// One blocking HTTP/1.1 GET against the metrics endpoint; returns
/// (status line, body).
fn scrape(addr: &str) -> (String, String) {
    let mut s = TcpStream::connect(addr).expect("connect metrics endpoint");
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    write!(s, "GET /metrics HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n")
        .expect("send scrape");
    let mut raw = String::new();
    s.read_to_string(&mut raw).expect("read scrape");
    let (head, body) = raw.split_once("\r\n\r\n").expect("http header/body split");
    let status = head.lines().next().unwrap_or_default().to_string();
    (status, body.to_string())
}

fn main() {
    let backend = start_backend();
    let sock = std::env::temp_dir().join(format!("icr_obs_smoke_{}.sock", std::process::id()));
    std::fs::remove_file(&sock).ok();

    let cfg = ServerConfig {
        model: smoke_model(),
        workers: 2,
        max_batch: 8,
        max_wait_us: 500,
        apply_threads: 4,
        idle_timeout_ms: 0,
        listen: ListenAddr::Unix(sock.clone()),
        replicas: vec![ReplicaSpec::new(
            "gp",
            vec![
                MemberSpec::local(Backend::Native),
                MemberSpec::remote(&format!("tcp:{}", backend.addr)).expect("remote member"),
            ],
        )
        .expect("replica spec")],
        trace_sample_rate: 1.0,
        metrics_listen: Some("tcp:127.0.0.1:0".into()),
        ..ServerConfig::default()
    };
    let front = Arc::new(Coordinator::start(cfg.clone()).expect("front door"));
    let engine = front.engine().clone();
    let server = NetServer::bind(&cfg, front.clone()).expect("bind front");
    let metrics_addr = server
        .metrics_addr()
        .expect("metrics endpoint bound")
        .strip_prefix("tcp:")
        .expect("tcp metrics addr")
        .to_string();
    let stop = server.shutdown_handle();
    let handle = std::thread::spawn(move || server.run());

    let mut c = Client::connect(&sock);

    // Byte parity: untraced replies never carry a trace field, and the
    // samples match the single-node engine bit-for-bit.
    for seed in 0..16u64 {
        let frame =
            format!(r#"{{"v": 2, "op": "sample", "model": "gp", "id": {seed}, "count": 1, "seed": {seed}}}"#);
        c.send(&frame);
        let line = c.recv_line();
        assert!(!line.contains("\"trace\""), "untraced reply leaked a trace field: {line}");
        let v = Value::parse(&line).expect("frame");
        assert_eq!(v.get("ok").and_then(Value::as_bool), Some(true), "{v:?}");
        let got: Vec<f64> = v
            .get_path("result.samples")
            .and_then(Value::as_array)
            .expect("samples")[0]
            .as_array()
            .expect("row")
            .iter()
            .filter_map(Value::as_f64)
            .collect();
        assert_eq!(got, engine.sample(1, seed).unwrap().remove(0), "seed {seed} diverged");
    }
    println!("PASS byte parity: 16 untraced replies byte-identical, no trace field");

    // Explicit trace on a request pinned to the remote member: the
    // reply echoes the joined span tree.
    let v = c.rpc(
        r#"{"v": 2, "op": "sample", "model": "gp@1", "id": 99, "count": 1, "seed": 424, "trace": true}"#,
    );
    assert_eq!(v.get("ok").and_then(Value::as_bool), Some(true), "{v:?}");
    let trace = v.get("trace").expect("traced reply echoes its span tree").clone();
    let spans = trace.get("spans").and_then(Value::as_array).expect("spans");
    let names: Vec<&str> =
        spans.iter().filter_map(|s| s.get("name").and_then(Value::as_str)).collect();
    for want in ["request", "remote_wire", "remote:request", "serialize_reply"] {
        assert!(names.contains(&want), "span {want:?} missing from {names:?}");
    }
    let wire_us = spans
        .iter()
        .find(|s| s.get("name").and_then(Value::as_str) == Some("remote_wire"))
        .and_then(|s| s.get("dur_us").and_then(Value::as_usize))
        .expect("remote_wire dur_us");
    assert!(wire_us >= 10_000, "remote_wire {wire_us}us < injected 10ms backend delay");
    println!("PASS trace echo: spans {names:?}, remote_wire {wire_us}us >= 10ms");

    // The ring committed the sampled traces and serves them over v2.
    let v = c.rpc(r#"{"v": 2, "op": "traces", "id": 100, "limit": 5}"#);
    let traces = v.get_path("result.traces").and_then(Value::as_array).expect("traces");
    assert!(!traces.is_empty(), "trace ring empty after 17 sampled requests");
    println!("PASS traces op: {} committed span trees returned", traces.len());

    // A real HTTP scrape answers Prometheus text format 0.0.4.
    let (status, body) = scrape(&metrics_addr);
    assert!(status.contains("200"), "scrape status: {status}");
    for want in [
        "# TYPE icr_uptime_seconds gauge",
        "icr_build_info{version=",
        "icr_requests_submitted_total{scope=\"global\"}",
        "scope=\"model\"",
        "_bucket{",
    ] {
        assert!(body.contains(want), "scrape missing {want:?}:\n{body}");
    }
    assert!(!body.contains("NaN"), "scrape leaked a NaN sample:\n{body}");
    println!("PASS metrics scrape: {} bytes of Prometheus text from {metrics_addr}", body.len());

    // §14: profile a burst of pooled panel-apply load on the default
    // (local) model, then dump the folded collapsed-stack document.
    let v = c.rpc(
        r#"{"v": 2, "op": "profile", "id": 200, "action": "start", "duration_ms": 60000}"#,
    );
    assert_eq!(
        v.get_path("result.profile.running").and_then(Value::as_bool),
        Some(true),
        "profiler did not start: {v:?}"
    );
    for i in 0..24u64 {
        c.send(&format!(
            r#"{{"v": 2, "op": "sample", "id": {}, "count": 8, "seed": {}}}"#,
            300 + i,
            7_000 + i,
        ));
    }
    for _ in 0..24 {
        let line = c.recv_line();
        let v = Value::parse(&line).expect("frame");
        assert_eq!(v.get("ok").and_then(Value::as_bool), Some(true), "{v:?}");
    }
    let v = c.rpc(r#"{"v": 2, "op": "profile", "id": 330, "action": "stop"}"#);
    assert_eq!(
        v.get_path("result.profile.running").and_then(Value::as_bool),
        Some(false),
        "profiler did not stop: {v:?}"
    );
    let v = c.rpc(r#"{"v": 2, "op": "profile", "id": 331, "action": "dump"}"#);
    let folded = v
        .get_path("result.profile.folded")
        .and_then(Value::as_str)
        .expect("folded dump")
        .to_string();
    assert!(
        folded.contains("request;panel_apply "),
        "folded profile missing panel_apply:\n{folded}"
    );
    assert!(
        folded.contains("request;serialize_reply "),
        "folded profile missing serialize_reply:\n{folded}"
    );
    println!("PASS profile op: folded dump with {} phase line(s)", folded.lines().count());

    // The pooled load left nonzero worker busy-seconds and a saturation
    // gauge in the exposition.
    let (status, body2) = scrape(&metrics_addr);
    assert!(status.contains("200"), "second scrape status: {status}");
    let busy: f64 = body2
        .lines()
        .filter(|l| l.starts_with("icr_pool_worker_busy_seconds_total{"))
        .filter_map(|l| l.rsplit(' ').next().and_then(|v| v.parse::<f64>().ok()))
        .sum();
    assert!(busy > 0.0, "pool busy-seconds still zero after pooled load:\n{body2}");
    assert!(
        body2.contains("icr_pool_saturation"),
        "scrape missing the pool saturation gauge:\n{body2}"
    );
    assert!(
        body2.contains("icr_process_resident_memory_bytes"),
        "scrape missing process self-stats:\n{body2}"
    );
    println!("PASS pool telemetry: {busy:.6} busy-seconds across lanes + saturation gauge");

    // Artifacts for CI upload.
    let dir = PathBuf::from(std::env::var("ICR_OBS_DIR").unwrap_or_else(|_| "obs-smoke".into()));
    std::fs::create_dir_all(&dir).expect("artifact dir");
    std::fs::write(dir.join("metrics.txt"), &body2).expect("write metrics.txt");
    std::fs::write(dir.join("trace.json"), trace.to_json()).expect("write trace.json");
    std::fs::write(dir.join("profile.folded"), &folded).expect("write profile.folded");
    println!("PASS artifacts: {}/metrics.txt + trace.json + profile.folded", dir.display());

    drop(c);
    stop.store(true, Ordering::SeqCst);
    handle.join().expect("front join").expect("front run");
    backend.stop.store(true, Ordering::SeqCst);
    backend.handle.join().expect("backend join").expect("backend run");
    std::fs::remove_file(&sock).ok();
    println!("obs_smoke: all checks passed");
}
