//! Galactic dust map (laptop-scale): the application that ran ICR with
//! 122 *billion* parameters (paper §6, ref [24] — the Galactic 3D dust
//! distribution via GP regression on spherical coordinates).
//!
//! The real reconstruction models log-dust-extinction on a spherical grid
//! with a logarithmic radial axis. Here we build the same *structure* at
//! laptop scale: a separable GP on (log-radius × galactic longitude),
//! using the Kronecker identity `√(K_r ⊗ K_ℓ) = √K_r ⊗ √K_ℓ` — each axis
//! gets its own 1-D ICR engine (log chart radially, regular chart in
//! longitude, broadcasting the stationary refinement matrices exactly as
//! §4.3 describes for invariant axes).
//!
//! Run: `cargo run --release --example galactic_dust`

use icr::chart::{IdentityChart, LogChart};
use icr::icr::{Geometry, IcrEngine, RefinementParams};
use icr::kernels::Matern;
use icr::rng::Rng;

/// Apply a 1-D engine along the rows of an excitation matrix
/// (dof × m) → (n × m): `out[:, j] = √K · xi[:, j]`.
fn apply_axis0(engine: &IcrEngine, xi: &[f64], m: usize) -> Vec<f64> {
    let dof = engine.total_dof();
    let n = engine.n_points();
    assert_eq!(xi.len(), dof * m);
    let mut out = vec![0.0; n * m];
    let mut col = vec![0.0; dof];
    for j in 0..m {
        for i in 0..dof {
            col[i] = xi[i * m + j];
        }
        let s = engine.apply_sqrt(&col);
        for i in 0..n {
            out[i * m + j] = s[i];
        }
    }
    out
}

/// Apply along rows: (r × dof) → (r × n): `out[i, :] = √K · xi[i, :]`.
fn apply_axis1(engine: &IcrEngine, xi: &[f64], r: usize) -> Vec<f64> {
    let dof = engine.total_dof();
    let n = engine.n_points();
    assert_eq!(xi.len(), r * dof);
    let mut out = vec![0.0; r * n];
    for i in 0..r {
        let s = engine.apply_sqrt(&xi[i * dof..(i + 1) * dof]);
        out[i * n..(i + 1) * n].copy_from_slice(&s);
    }
    out
}

fn main() -> anyhow::Result<()> {
    // Radial axis: dust correlations with ρ = 0.5 kpc on distances from
    // 60 pc to ~16 kpc — a log chart, exactly the [24] geometry.
    let radial_params = RefinementParams::for_target(5, 4, 6, 1500)?;
    let rgeo = Geometry::build(radial_params);
    let rfin = rgeo.final_positions();
    let (u0, u1) = (rfin[0], rfin[rfin.len() - 1]);
    let beta = (16.0_f64 / 0.06).ln() / (u1 - u0);
    let alpha = 0.06_f64.ln() - beta * u0;
    let radial_chart = LogChart::new(alpha, beta);
    let radial_kernel = Matern::nu32(0.5, 1.0);
    let radial = IcrEngine::build(&radial_kernel, &radial_chart, radial_params)?;

    // Longitude axis: translation invariant ⇒ stationary broadcast path.
    let lon_params = RefinementParams::for_target(3, 2, 5, 360)?;
    let lon_kernel = Matern::nu32(12.0, 1.0); // ~12° correlation length
    let lon = IcrEngine::build(&lon_kernel, &IdentityChart::unit(), lon_params)?;

    let (nr, nl) = (radial.n_points(), lon.n_points());
    println!(
        "dust grid: {nr} radial (log, {:.2}…{:.1} kpc) × {nl} longitude = {} voxels",
        radial.domain_points()[0],
        radial.domain_points()[nr - 1],
        nr * nl
    );
    println!(
        "radial engine stationary: {} | longitude engine stationary: {} (broadcast fast path)",
        radial.is_stationary(),
        lon.is_stationary()
    );

    // Sample the separable field: s = √K_r · Ξ · √K_ℓᵀ.
    let mut rng = Rng::new(122_000_000_000);
    let t0 = std::time::Instant::now();
    let xi: Vec<f64> = rng.standard_normal_vec(radial.total_dof() * lon.total_dof());
    let half = apply_axis1(&lon, &xi, radial.total_dof()); // radial-dof × nl
    let field = apply_axis0(&radial, &half, nl); // nr × nl
    let dt = t0.elapsed();
    println!(
        "sampled {}-voxel log-dust field in {:.1} ms ({:.0} ns/voxel — O(N), Eq. 13)",
        nr * nl,
        dt.as_secs_f64() * 1e3,
        dt.as_nanos() as f64 / (nr * nl) as f64
    );

    // Column statistics: the marginal variance must be ≈ k_r(0)·k_ℓ(0) = 1.
    let mean: f64 = field.iter().sum::<f64>() / field.len() as f64;
    let var: f64 = field.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / field.len() as f64;
    println!("field stats: mean {mean:+.3}, var {var:.3} (expected ≈ 1.0)");
    anyhow::ensure!((var - 1.0).abs() < 0.5, "marginal variance off: {var}");

    // Dust density = exp(log-field): report a simple observable, the
    // radial profile of the mean density (averaged over longitude).
    println!("\nradial mean-density profile (every ~{}th shell):", nr / 8);
    for i in (0..nr).step_by((nr / 8).max(1)) {
        let row_mean: f64 =
            (0..nl).map(|j| field[i * nl + j].exp()).sum::<f64>() / nl as f64;
        let r = radial.domain_points()[i];
        let bar = "#".repeat((row_mean * 10.0).min(60.0) as usize);
        println!("  r = {r:8.2} kpc  ⟨ρ⟩ = {row_mean:6.3}  {bar}");
    }

    // Empirical radial correlation vs the kernel (sanity of the Kronecker
    // construction): corr(s[i0,:], s[i1,:]) ≈ k_r(d)·1 normalized.
    let i0 = nr / 2;
    let corr = |a: usize, b: usize| -> f64 {
        let (ra, rb) = (&field[a * nl..(a + 1) * nl], &field[b * nl..(b + 1) * nl]);
        let dot: f64 = ra.iter().zip(rb).map(|(x, y)| x * y).sum();
        let na: f64 = ra.iter().map(|x| x * x).sum::<f64>().sqrt();
        let nb: f64 = rb.iter().map(|x| x * x).sum::<f64>().sqrt();
        dot / (na * nb)
    };
    println!("\nradial correlation from one sample (vs kernel):");
    for di in [1usize, 4, 16, 64] {
        let i1 = (i0 + di).min(nr - 1);
        let d = (radial.domain_points()[i1] - radial.domain_points()[i0]).abs();
        println!(
            "  Δr = {d:7.3} kpc: empirical {:+.3}, kernel {:+.3}",
            corr(i0, i1),
            icr::kernels::Kernel::eval(&radial_kernel, d)
        );
    }
    Ok(())
}
